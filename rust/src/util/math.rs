//! Numeric helpers shared across the coordinator: radix/quick-select for
//! Top-K thresholds, stable statistics, and unit formatting.

/// k-th largest absolute value of `xs` (1-based k) — the wire-compression
/// hot path (a threshold is computed for every cross-node message).
///
/// Radix select over the f32 bit patterns: for non-negative floats the IEEE
/// bit pattern is monotone in value, so |x| reduces to `bits & 0x7FFF_FFFF`
/// and selection proceeds byte-by-byte over histograms — two streaming
/// passes and a small tail sort, no swaps. ~16x faster than the quickselect
/// it replaced (see EXPERIMENTS.md §Perf).
pub fn kth_largest_abs(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} len={}", xs.len());
    // Small inputs: sorting is simpler and faster.
    if xs.len() <= 512 {
        let mut v: Vec<u32> = xs.iter().map(|x| x.to_bits() & 0x7FFF_FFFF).collect();
        v.sort_unstable();
        return f32::from_bits(v[v.len() - k]);
    }

    // Multi-level radix select over the 31-bit magnitude patterns: refine
    // one byte per level, narrowing the candidate set each time. Floats
    // cluster by exponent, so a single level can leave most of the data in
    // one bucket — the recursion handles any distribution in O(n) total.
    let mut remaining = k;
    let mut prefix: u32 = 0;
    let mut prefix_mask: u32 = 0;
    let mut cand: Vec<u32> = Vec::new(); // empty sentinel = "all of xs"
    for shift in [24u32, 16, 8, 0] {
        // Histogram of this level's byte among prefix-matching candidates.
        let mut hist = [0usize; 256];
        if cand.is_empty() {
            for x in xs {
                let b = x.to_bits() & 0x7FFF_FFFF;
                hist[((b >> shift) & 0xFF) as usize] += 1;
            }
        } else {
            for &b in &cand {
                hist[((b >> shift) & 0xFF) as usize] += 1;
            }
        }
        // Walk buckets from the top to locate the k-th largest.
        let mut bucket = 255usize;
        loop {
            if hist[bucket] >= remaining {
                break;
            }
            remaining -= hist[bucket];
            if bucket == 0 {
                break;
            }
            bucket -= 1;
        }
        prefix |= (bucket as u32) << shift;
        prefix_mask |= 0xFFu32 << shift;
        if shift == 0 {
            break; // all 32 bits determined
        }
        // Gather the next candidate set.
        cand = if cand.is_empty() {
            xs.iter()
                .map(|x| x.to_bits() & 0x7FFF_FFFF)
                .filter(|b| b & prefix_mask == prefix)
                .collect()
        } else {
            cand.into_iter().filter(|b| b & prefix_mask == prefix).collect()
        };
        if cand.len() <= 2048 {
            // Small tail: sort and index directly.
            cand.sort_unstable();
            return f32::from_bits(cand[cand.len() - remaining]);
        }
    }
    f32::from_bits(prefix)
}

/// Quickselect variant kept for the §Perf ablation and as a cross-check
/// oracle in tests.
pub fn kth_largest_abs_quickselect(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} len={}", xs.len());
    let mut buf: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    // k-th largest == (len-k)-th smallest (0-based).
    let target = buf.len() - k;
    let (mut lo, mut hi) = (0usize, buf.len() - 1);
    // Deterministic median-of-three pivoting.
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        // median of buf[lo], buf[mid], buf[hi]
        let (a, b, c) = (buf[lo], buf[mid], buf[hi]);
        let pivot = if (a <= b) == (b <= c) {
            b
        } else if (b <= a) == (a <= c) {
            a
        } else {
            c
        };
        // 3-way partition (Dutch national flag) to handle duplicates fast.
        let (mut i, mut j, mut p) = (lo, lo, hi);
        while j <= p {
            if buf[j] < pivot {
                buf.swap(i, j);
                i += 1;
                j += 1;
            } else if buf[j] > pivot {
                buf.swap(j, p);
                if p == 0 {
                    break;
                }
                p -= 1;
            } else {
                j += 1;
            }
        }
        if target < i {
            if i == 0 {
                break;
            }
            hi = i - 1;
        } else if target > p {
            lo = p + 1;
        } else {
            return pivot;
        }
    }
    buf[target.min(buf.len() - 1)]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts; for reporting only).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Simple least-squares fit y = a + b·x, returns (a, b).
/// Used to fit the λ scaling factor and alpha-beta link models from
/// warm-up profiling measurements (§3.5 of the paper).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn kth_ref(xs: &[f32], k: usize) -> f32 {
        let mut v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v[k - 1]
    }

    #[test]
    fn kth_largest_matches_sort_reference() {
        let mut rng = Rng::new(123);
        for trial in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let got = kth_largest_abs(&xs, k);
            let want = kth_ref(&xs, k);
            assert_eq!(got, want, "trial {trial} n={n} k={k}");
            assert_eq!(kth_largest_abs_quickselect(&xs, k), want);
        }
    }

    #[test]
    fn kth_largest_radix_path_matches_reference() {
        // Force the >512 radix path with varied distributions.
        let mut rng = Rng::new(321);
        for trial in 0..20 {
            let n = 600 + rng.below(5000) as usize;
            let scale = 10f32.powi(rng.range(-6, 6) as i32);
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * scale).collect();
            for k in [1, 7, n / 100 + 1, n / 2, n] {
                let got = kth_largest_abs(&xs, k);
                let want = kth_ref(&xs, k);
                assert_eq!(got, want, "trial {trial} n={n} k={k}");
            }
        }
    }

    #[test]
    fn kth_largest_radix_with_zeros_and_duplicates() {
        let mut xs = vec![0.0f32; 1000];
        xs[10] = 3.0;
        xs[900] = -5.0;
        assert_eq!(kth_largest_abs(&xs, 1), 5.0);
        assert_eq!(kth_largest_abs(&xs, 2), 3.0);
        assert_eq!(kth_largest_abs(&xs, 3), 0.0);
        assert_eq!(kth_largest_abs(&xs, 1000), 0.0);
        let xs = vec![2.5f32; 4096];
        assert_eq!(kth_largest_abs(&xs, 1), 2.5);
        assert_eq!(kth_largest_abs(&xs, 4096), 2.5);
    }

    #[test]
    fn kth_with_duplicates() {
        let xs = vec![1.0f32; 64];
        assert_eq!(kth_largest_abs(&xs, 1), 1.0);
        assert_eq!(kth_largest_abs(&xs, 64), 1.0);
        let xs = vec![2.0, -2.0, 2.0, 1.0, -1.0];
        assert_eq!(kth_largest_abs(&xs, 3), 2.0);
        assert_eq!(kth_largest_abs(&xs, 4), 1.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stats_sane() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!(std_dev(&xs) > 1.0 && std_dev(&xs) < 1.2);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(5.0).contains("s"));
    }
}

#[cfg(test)]
mod perf_probe {
    use super::*;
    use crate::util::rng::Rng;
    #[test]
    #[ignore]
    fn breakdown() {
        let mut rng = Rng::new(7);
        let n = 3 * 1024 * 1600;
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let k = n / 100;
        let t0 = std::time::Instant::now();
        for _ in 0..5 { std::hint::black_box(kth_largest_abs(&xs, k)); }
        println!("kth_largest_abs: {:?}/iter", t0.elapsed() / 5);
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            let v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
            std::hint::black_box(v);
        }
        println!("abs copy: {:?}/iter", t0.elapsed() / 5);
    }
}
