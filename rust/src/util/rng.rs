//! Deterministic PRNG (xoshiro256**) — no `rand` crate offline.
//!
//! Used for synthetic data generation, testbed jitter, Random-K compression
//! and the proptest-lite generators. Seeded explicitly everywhere so every
//! experiment is reproducible.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion, as recommended by the authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Uses Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-300 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fill a slice with N(0, std) f32 samples.
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out {
            *v = self.normal() as f32 * std;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Zipf-distributed integer in [0, n) with exponent `a` (rejection-free
    /// inverse-CDF over precomputed weights is overkill; use simple CDF walk
    /// cached by the caller when hot).
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf CDF over n items with exponent a.
pub fn zipf_cdf(n: usize, a: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|k| 1.0 / (k as f64).powf(a)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn zipf_cdf_monotone_ends_at_one() {
        let cdf = zipf_cdf(100, 1.1);
        assert!(cdf.windows(2).all(|w| w[0] <= w[1]));
        assert!((cdf[99] - 1.0).abs() < 1e-9);
        let mut r = Rng::new(3);
        // Rank-0 should be the most frequent draw.
        let mut counts = [0usize; 100];
        for _ in 0..5000 {
            counts[r.zipf(&cdf)] += 1;
        }
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
