//! FNV-1a 64 — the one checksum of the codebase (socket frames, checkpoint
//! manifests). No crypto needed: it guards against torn writes, bit rot and
//! stream desync, not adversaries.
//!
//! This module is the canonical home (previously `checkpoint::fnv1a64`,
//! which `transport::frame` reached *up* into — the dependency now points
//! the right way, and `checkpoint` re-exports for compatibility).
//!
//! FNV-1a's hash chain is sequentially dependent (each byte's multiply
//! feeds the next xor), so true SIMD lanes cannot apply; the dispatched
//! form is an 8-way unrolled scalar pipeline instead — same chain, more
//! instruction-level parallelism, bitwise identical by construction. The
//! `util::simd` dispatch level still gates it so `FUSIONLLM_FORCE_SCALAR`
//! pins the byte-at-a-time reference.

use crate::util::simd::{self, Level};

/// FNV-1a 64 offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64 prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64 over a byte stream (no crypto needed — this guards against
/// torn writes and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    chunk(FNV_OFFSET, bytes, simd::level())
}

/// Byte-at-a-time reference implementation (the forced-scalar path and the
/// differential-test oracle).
pub fn fnv1a64_scalar(bytes: &[u8]) -> u64 {
    chunk_scalar(FNV_OFFSET, bytes)
}

/// `fnv1a64` pinned to an explicit dispatch level (differential tests).
pub fn fnv1a64_at(level: Level, bytes: &[u8]) -> u64 {
    chunk(FNV_OFFSET, bytes, level)
}

/// Streaming FNV-1a 64: feed disjoint byte regions with `update`, read the
/// digest with `finish`. `Fnv::new().update(a).update(b)` over split
/// regions equals `fnv1a64` over their concatenation — the vectored frame
/// writer checksums header and body without staging them contiguously.
#[derive(Debug, Clone, Copy)]
pub struct Fnv(u64);

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv::new()
    }
}

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(FNV_OFFSET)
    }

    pub fn update(&mut self, bytes: &[u8]) -> &mut Fnv {
        self.0 = chunk(self.0, bytes, simd::level());
        self
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

fn chunk(h: u64, bytes: &[u8], level: Level) -> u64 {
    match level {
        Level::Scalar => chunk_scalar(h, bytes),
        _ => chunk_unrolled(h, bytes),
    }
}

fn chunk_scalar(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

fn chunk_unrolled(mut h: u64, bytes: &[u8]) -> u64 {
    let mut it = bytes.chunks_exact(8);
    for c in &mut it {
        h = (h ^ c[0] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[1] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[2] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[3] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[4] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[5] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[6] as u64).wrapping_mul(FNV_PRIME);
        h = (h ^ c[7] as u64).wrapping_mul(FNV_PRIME);
    }
    chunk_scalar(h, it.remainder())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Official FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64_scalar(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64_scalar(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn unrolled_matches_scalar_on_ragged_lengths() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i.wrapping_mul(2654435761) >> 13) as u8).collect();
        for n in [0, 1, 3, 7, 8, 9, 15, 16, 17, 63, 64, 65, 255, 1000, 1024] {
            assert_eq!(
                chunk_unrolled(FNV_OFFSET, &data[..n]),
                chunk_scalar(FNV_OFFSET, &data[..n]),
                "n={n}"
            );
        }
    }

    #[test]
    fn streaming_splits_match_oneshot() {
        let data: Vec<u8> = (0..777u32).map(|i| (i.wrapping_mul(0x9E3779B9) >> 11) as u8).collect();
        let want = fnv1a64(&data);
        for split in [0, 1, 7, 8, 100, 776, 777] {
            let mut f = Fnv::new();
            f.update(&data[..split]).update(&data[split..]);
            assert_eq!(f.finish(), want, "split={split}");
        }
        // Three-way split (the frame writer's header/body/etc. shape).
        let mut f = Fnv::new();
        f.update(&data[..8]);
        f.update(&data[8..512]);
        f.update(&data[512..]);
        assert_eq!(f.finish(), want);
    }
}
