//! Tiny CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed getters and a usage printer. Subcommand dispatch is done by the
//! binary itself (first positional).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    present: Vec<String>,
}

impl Args {
    /// Parse raw argv (excluding program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                    out.present.push(k.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                    out.present.push(rest.to_string());
                } else {
                    out.flags.insert(rest.to_string(), String::new());
                    out.present.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got `{v}`")))
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.f64(key, default as f64) as f32
    }

    /// Keys the user actually passed (for echoing config in logs).
    pub fn passed(&self) -> &[String] {
        &self.present
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // Positionals come before flags; a bare trailing `--flag` is boolean.
        let a = parse("train extra --steps 10 --lr=0.5 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.usize("steps", 0), 10);
        assert_eq!(a.f64("lr", 0.0), 0.5);
        assert!(a.has("verbose"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize("steps", 7), 7);
        assert_eq!(a.str("mode", "sim"), "sim");
    }

    #[test]
    fn flag_then_flag() {
        let a = parse("--x --y 3");
        assert!(a.has("x"));
        assert_eq!(a.usize("y", 0), 3);
    }
}
