//! Minimal JSON parser/writer (RFC 8259 subset, no serde — offline build).
//!
//! Used for the AOT artifact manifest, job configs and metrics dumps.
//! Numbers are kept as f64; integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let s = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Ok(Json::parse(&s).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e15 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers returning errors naming the key.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field `{key}`"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid array field `{key}`"))
    }

    // -- writer --------------------------------------------------------------

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    pub fn dump_pretty(&self) -> String {
        let mut s = String::new();
        self.write_indent(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_indent(&self, out: &mut String, level: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, level + 1);
                    v.write_indent(out, level + 1);
                }
                out.push('\n');
                indent(out, level);
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, level + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_indent(out, level + 1);
                }
                out.push('\n');
                indent(out, level);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn indent(out: &mut String, level: usize) {
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for building manifests/configs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn n(v: f64) -> Json {
    Json::Num(v)
}

pub fn ni(v: usize) -> Json {
    Json::Num(v as f64)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            map.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: combine if a high surrogate.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                code
                            };
                            s.push(char::from_u32(ch).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                c => {
                    // Re-scan multibyte UTF-8 starting at c.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("bad utf8"))?;
                        let st = std::str::from_utf8(bytes).map_err(|_| self.err("bad utf8"))?;
                        s.push_str(st);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(src).unwrap();
            assert_eq!(Json::parse(&v.dump()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn numbers_roundtrip() {
        let v = Json::parse("[1e3, 0.25, -7]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), 1000.0);
        assert_eq!(a[1].as_f64().unwrap(), 0.25);
        assert_eq!(a[2].as_i64().unwrap(), -7);
        assert_eq!(v.dump(), "[1000,0.25,-7]");
    }

    #[test]
    fn pretty_is_reparseable() {
        let v = obj(vec![("x", arr(vec![ni(1), ni(2)])), ("y", s("z"))]);
        assert_eq!(Json::parse(&v.dump_pretty()).unwrap(), v);
    }

    #[test]
    fn req_accessors_error_on_missing() {
        let v = obj(vec![("k", ni(3))]);
        assert_eq!(v.req_usize("k").unwrap(), 3);
        assert!(v.req_str("k").is_err());
        assert!(v.req_f64("missing").is_err());
    }
}
