//! ASCII table printer used by the benchmark harnesses to print
//! paper-style tables (Table 1, Fig. 10/11 matrices).

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Table {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = w[i].max(h.len());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer", "22"]);
        let s = t.render();
        assert!(s.contains("| name   | value |"));
        assert!(s.contains("| longer | 22    |"));
        // All lines equal width.
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
