//! Small self-contained substrates: JSON, CLI parsing, RNG, math helpers.
//! These exist because the build is fully offline — only the `xla` crate
//! dependency closure is vendored, so serde/clap/rand are hand-rolled here.

pub mod benchkit;
pub mod cli;
pub mod fnv;
pub mod json;
pub mod math;
pub mod rng;
pub mod simd;
pub mod table;
