//! Runtime-dispatched SIMD kernels for the per-message wire hot path:
//! int8 quantize/dequantize, sparse gather/scatter, the abs-bits pass
//! feeding the radix Top-K select, absmax reduction, and bulk
//! little-endian moves.
//!
//! # Dispatch
//!
//! Every kernel has three entry points: the plain name (dispatched on the
//! process-wide [`level()`]), a `_scalar` reference, and an `_at` form
//! pinned to an explicit [`Level`] (differential tests iterate
//! [`Level::supported()`] so the SSE2 path is exercised even on AVX2
//! hosts). The level is detected once: AVX2 → SSE2 (the x86_64 baseline)
//! → portable scalar, overridable with `FUSIONLLM_FORCE_SCALAR=1` or the
//! `force-scalar` cargo feature.
//!
//! # Bitwise contract
//!
//! The chan-vs-tcp-vs-mesh and overlap-on/off differential gates pin
//! *bitwise* losses, so every SIMD path here must produce byte-identical
//! results to its scalar reference — not merely close ones. The hard case
//! is int8 quantization: `f32::round` is round-half-away-from-zero while
//! the SSE/AVX rounding ops are round-half-even, so the vector paths
//! reconstruct the scalar rounding exactly (truncate, exact fractional
//! remainder, ±1 fix-up when |frac| ≥ 0.5) and handle the |x| ≥ 2^31 /
//! NaN saturation cases of Rust's `as` casts explicitly. Reductions
//! (absmax) are order-independent over magnitudes, so lane-parallel max
//! is exact; NaN inputs are outside the contract there (the trainer never
//! produces them — scalar `fold(max)` would itself be order-sensitive).

use std::sync::OnceLock;

/// IEEE-754 f32 magnitude mask: |x| is monotone in `bits & ABS_MASK`.
const ABS_MASK: u32 = 0x7FFF_FFFF;

/// Index block size for the scatter/gather kernels: bounds checks hoist
/// to one compare per block, value dequantization runs SIMD-wide into a
/// stack buffer, stores stay in input order (duplicate index = last
/// write wins, exactly like the scalar loop).
const BLOCK: usize = 64;

/// Dispatch level for every kernel in this module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Portable reference path (also the non-x86 and forced-scalar path).
    Scalar,
    /// 128-bit vectors; baseline on x86_64, never runtime-gated.
    Sse2,
    /// 256-bit vectors, runtime-detected.
    Avx2,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Sse2 => "sse2",
            Level::Avx2 => "avx2",
        }
    }

    /// Every level this machine can execute, scalar first. Differential
    /// tests compare each against `Scalar`; `_at` callers must pass a
    /// level from this list (or `Scalar`, which is always valid).
    pub fn supported() -> Vec<Level> {
        #[cfg(target_arch = "x86_64")]
        {
            let mut v = vec![Level::Scalar, Level::Sse2];
            if is_x86_feature_detected!("avx2") {
                v.push(Level::Avx2);
            }
            v
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            vec![Level::Scalar]
        }
    }
}

/// The process-wide dispatch level, detected once. `FUSIONLLM_FORCE_SCALAR`
/// (1/true/yes) or the `force-scalar` cargo feature pin it to `Scalar` —
/// the escape hatch if a platform's vector path ever misbehaves, and the
/// lever the forced-scalar CI job uses to keep the fallback green.
pub fn level() -> Level {
    static L: OnceLock<Level> = OnceLock::new();
    *L.get_or_init(detect)
}

fn detect() -> Level {
    if cfg!(feature = "force-scalar") || force_scalar_env() {
        return Level::Scalar;
    }
    arch_level()
}

fn force_scalar_env() -> bool {
    match std::env::var("FUSIONLLM_FORCE_SCALAR") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
        }
        Err(_) => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn arch_level() -> Level {
    if is_x86_feature_detected!("avx2") {
        Level::Avx2
    } else {
        Level::Sse2
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn arch_level() -> Level {
    Level::Scalar
}

// ---- absmax reduction --------------------------------------------------

/// `fold(0.0, |a, v| a.max(v.abs()))` — the absmax pass feeding the int8
/// scale. Max over magnitudes is order-independent, so the lane-parallel
/// reduction is bitwise identical to the sequential fold for every finite
/// input (NaNs are outside the contract: the trainer never produces them,
/// and the scalar fold is itself order-sensitive under NaN).
pub fn max_abs(xs: &[f32]) -> f32 {
    max_abs_at(level(), xs)
}

pub fn max_abs_scalar(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
}

pub fn max_abs_at(level: Level, xs: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    match level {
        Level::Avx2 => return unsafe { max_abs_avx2(xs) },
        Level::Sse2 => return max_abs_sse2(xs),
        Level::Scalar => {}
    }
    let _ = level;
    max_abs_scalar(xs)
}

#[cfg(target_arch = "x86_64")]
fn max_abs_sse2(xs: &[f32]) -> f32 {
    // SAFETY: SSE2 is part of the x86_64 baseline ABI.
    unsafe {
        use std::arch::x86_64::*;
        let mask = _mm_castsi128_ps(_mm_set1_epi32(ABS_MASK as i32));
        let mut acc = _mm_setzero_ps();
        let mut chunks = xs.chunks_exact(4);
        for c in &mut chunks {
            let v = _mm_loadu_ps(c.as_ptr());
            acc = _mm_max_ps(acc, _mm_and_ps(v, mask));
        }
        let mut lanes = [0.0f32; 4];
        _mm_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut m = lanes.iter().fold(0.0f32, |a, &l| a.max(l));
        for &v in chunks.remainder() {
            m = m.max(v.abs());
        }
        m
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_abs_avx2(xs: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let mask = _mm256_castsi256_ps(_mm256_set1_epi32(ABS_MASK as i32));
    let mut acc = _mm256_setzero_ps();
    let mut chunks = xs.chunks_exact(8);
    for c in &mut chunks {
        let v = _mm256_loadu_ps(c.as_ptr());
        acc = _mm256_max_ps(acc, _mm256_and_ps(v, mask));
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut m = lanes.iter().fold(0.0f32, |a, &l| a.max(l));
    for &v in chunks.remainder() {
        m = m.max(v.abs());
    }
    m
}

// ---- abs-bits pass -----------------------------------------------------

/// `out[i] = xs[i].to_bits() & 0x7FFF_FFFF` — the magnitude-bit-pattern
/// pass the radix Top-K select runs over every candidate. Pure integer
/// masking, so bitwise identity across levels is structural.
///
/// Panics if the slices differ in length.
pub fn abs_bits(xs: &[f32], out: &mut [u32]) {
    abs_bits_at(level(), xs, out)
}

pub fn abs_bits_scalar(xs: &[f32], out: &mut [u32]) {
    assert_eq!(xs.len(), out.len());
    for (o, x) in out.iter_mut().zip(xs) {
        *o = x.to_bits() & ABS_MASK;
    }
}

pub fn abs_bits_at(level: Level, xs: &[f32], out: &mut [u32]) {
    assert_eq!(xs.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    match level {
        Level::Avx2 => return unsafe { abs_bits_avx2(xs, out) },
        Level::Sse2 => return abs_bits_sse2(xs, out),
        Level::Scalar => {}
    }
    let _ = level;
    abs_bits_scalar(xs, out)
}

#[cfg(target_arch = "x86_64")]
fn abs_bits_sse2(xs: &[f32], out: &mut [u32]) {
    // SAFETY: SSE2 is part of the x86_64 baseline ABI; unaligned
    // loads/stores are used throughout.
    unsafe {
        use std::arch::x86_64::*;
        let mask = _mm_set1_epi32(ABS_MASK as i32);
        let mut xi = xs.chunks_exact(4);
        let mut oi = out.chunks_exact_mut(4);
        for (c, o) in (&mut xi).zip(&mut oi) {
            let v = _mm_loadu_si128(c.as_ptr() as *const __m128i);
            _mm_storeu_si128(o.as_mut_ptr() as *mut __m128i, _mm_and_si128(v, mask));
        }
        for (o, x) in oi.into_remainder().iter_mut().zip(xi.remainder()) {
            *o = x.to_bits() & ABS_MASK;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn abs_bits_avx2(xs: &[f32], out: &mut [u32]) {
    use std::arch::x86_64::*;
    let mask = _mm256_set1_epi32(ABS_MASK as i32);
    let mut xi = xs.chunks_exact(8);
    let mut oi = out.chunks_exact_mut(8);
    for (c, o) in (&mut xi).zip(&mut oi) {
        let v = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
        _mm256_storeu_si256(o.as_mut_ptr() as *mut __m256i, _mm256_and_si256(v, mask));
    }
    for (o, x) in oi.into_remainder().iter_mut().zip(xi.remainder()) {
        *o = x.to_bits() & ABS_MASK;
    }
}

// ---- int8 quantize -----------------------------------------------------

/// THE int8 code formula (round-to-nearest-half-away, saturating ±127;
/// `as i8 as u8` keeps the two's-complement byte). `compress::quant::code`
/// delegates here so the dense and sparse int8 wire formats cannot drift
/// from the SIMD paths.
#[inline]
pub fn quant_code(v: f32, scale: f32) -> u8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8 as u8
}

/// Append `quant_code(v, scale)` for every `v` — the int8 quantize pass.
/// Bitwise identical to the scalar form for *every* f32 input including
/// half-ulp rounding boundaries, |v/scale| ≥ 2^31, infinities and NaN
/// (which saturate/zero exactly like Rust `as i8`).
pub fn quantize_codes(values: &[f32], scale: f32, out: &mut Vec<u8>) {
    quantize_codes_at(level(), values, scale, out)
}

pub fn quantize_codes_scalar(values: &[f32], scale: f32, out: &mut Vec<u8>) {
    out.reserve(values.len());
    out.extend(values.iter().map(|&v| quant_code(v, scale)));
}

pub fn quantize_codes_at(level: Level, values: &[f32], scale: f32, out: &mut Vec<u8>) {
    #[cfg(target_arch = "x86_64")]
    match level {
        Level::Avx2 => {
            out.reserve(values.len());
            return unsafe { quantize_codes_avx2(values, scale, out) };
        }
        Level::Sse2 => {
            out.reserve(values.len());
            return quantize_codes_sse2(values, scale, out);
        }
        Level::Scalar => {}
    }
    let _ = level;
    quantize_codes_scalar(values, scale, out)
}

// Both vector paths reconstruct `f32::round` (half away from zero) from
// truncation:
//   x = v / scale                      (true IEEE divide, never reciprocal)
//   t = cvtepi32_ps(cvttps_epi32(x))   (trunc; exact for |x| < 2^31 —
//                                       above 2^23 every f32 is integral,
//                                       so the i32 round-trips exactly)
//   f = x - t                          (exact: multiple of ulp(x), < 2^24 ulps)
//   r = t + copysign(1, x) · [|f| ≥ 0.5]
// |f| ≥ 0.5 compares magnitude *bit patterns* against bits(0.5) so no
// float compare semantics leak in; lanes with |x| ≥ 2^31 (where cvttps is
// garbage) are overridden with the saturated ±127 Rust's `as` would
// produce, and NaN lanes are zeroed last (Rust saturating cast: NaN → 0).

#[cfg(target_arch = "x86_64")]
fn quantize_codes_sse2(values: &[f32], scale: f32, out: &mut Vec<u8>) {
    // SAFETY: SSE2 is part of the x86_64 baseline ABI.
    unsafe {
        use std::arch::x86_64::*;
        let s = _mm_set1_ps(scale);
        let abs_mask = _mm_set1_epi32(ABS_MASK as i32);
        let half_m1 = _mm_set1_epi32(0x3EFF_FFFF); // bits(0.5) - 1
        let big_m1 = _mm_set1_epi32(0x4EFF_FFFF); // bits(2^31) - 1
        let nan_min = _mm_set1_epi32(0x7F80_0000); // bits(+inf)
        let one = _mm_set1_ps(1.0);
        let sign_mask = _mm_set1_epi32(i32::MIN);
        let lo = _mm_set1_ps(-127.0);
        let hi = _mm_set1_ps(127.0);
        let zero = _mm_setzero_si128();
        let p127 = _mm_set1_epi32(127);
        let n127 = _mm_set1_epi32(-127);
        let mut chunks = values.chunks_exact(4);
        let mut lanes = [0i32; 4];
        for c in &mut chunks {
            let v = _mm_loadu_ps(c.as_ptr());
            let x = _mm_div_ps(v, s);
            let xb = _mm_castps_si128(x);
            let x_abs = _mm_and_si128(xb, abs_mask);
            let t = _mm_cvtepi32_ps(_mm_cvttps_epi32(x));
            let f = _mm_sub_ps(x, t);
            let f_abs = _mm_and_si128(_mm_castps_si128(f), abs_mask);
            let ge_half = _mm_cmpgt_epi32(f_abs, half_m1);
            let sone = _mm_or_ps(one, _mm_castsi128_ps(_mm_and_si128(xb, sign_mask)));
            let fix = _mm_and_ps(_mm_castsi128_ps(ge_half), sone);
            let r = _mm_min_ps(_mm_max_ps(_mm_add_ps(t, fix), lo), hi);
            let mut code = _mm_cvttps_epi32(r);
            let big = _mm_cmpgt_epi32(x_abs, big_m1);
            let neg = _mm_cmpgt_epi32(zero, xb);
            let sat = _mm_or_si128(_mm_and_si128(neg, n127), _mm_andnot_si128(neg, p127));
            code = _mm_or_si128(_mm_and_si128(big, sat), _mm_andnot_si128(big, code));
            let is_nan = _mm_cmpgt_epi32(x_abs, nan_min);
            code = _mm_andnot_si128(is_nan, code);
            _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, code);
            out.extend_from_slice(&[
                lanes[0] as u8,
                lanes[1] as u8,
                lanes[2] as u8,
                lanes[3] as u8,
            ]);
        }
        for &v in chunks.remainder() {
            out.push(quant_code(v, scale));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize_codes_avx2(values: &[f32], scale: f32, out: &mut Vec<u8>) {
    use std::arch::x86_64::*;
    let s = _mm256_set1_ps(scale);
    let abs_mask = _mm256_set1_epi32(ABS_MASK as i32);
    let half_m1 = _mm256_set1_epi32(0x3EFF_FFFF);
    let big_m1 = _mm256_set1_epi32(0x4EFF_FFFF);
    let nan_min = _mm256_set1_epi32(0x7F80_0000);
    let one = _mm256_set1_ps(1.0);
    let sign_mask = _mm256_set1_epi32(i32::MIN);
    let lo = _mm256_set1_ps(-127.0);
    let hi = _mm256_set1_ps(127.0);
    let zero = _mm256_setzero_si256();
    let p127 = _mm256_set1_epi32(127);
    let n127 = _mm256_set1_epi32(-127);
    let mut chunks = values.chunks_exact(8);
    let mut lanes = [0i32; 8];
    for c in &mut chunks {
        let v = _mm256_loadu_ps(c.as_ptr());
        let x = _mm256_div_ps(v, s);
        let xb = _mm256_castps_si256(x);
        let x_abs = _mm256_and_si256(xb, abs_mask);
        let t = _mm256_cvtepi32_ps(_mm256_cvttps_epi32(x));
        let f = _mm256_sub_ps(x, t);
        let f_abs = _mm256_and_si256(_mm256_castps_si256(f), abs_mask);
        let ge_half = _mm256_cmpgt_epi32(f_abs, half_m1);
        let sone = _mm256_or_ps(one, _mm256_castsi256_ps(_mm256_and_si256(xb, sign_mask)));
        let fix = _mm256_and_ps(_mm256_castsi256_ps(ge_half), sone);
        let r = _mm256_min_ps(_mm256_max_ps(_mm256_add_ps(t, fix), lo), hi);
        let mut code = _mm256_cvttps_epi32(r);
        let big = _mm256_cmpgt_epi32(x_abs, big_m1);
        let neg = _mm256_cmpgt_epi32(zero, xb);
        let sat = _mm256_or_si256(_mm256_and_si256(neg, n127), _mm256_andnot_si256(neg, p127));
        code = _mm256_or_si256(_mm256_and_si256(big, sat), _mm256_andnot_si256(big, code));
        let is_nan = _mm256_cmpgt_epi32(x_abs, nan_min);
        code = _mm256_andnot_si256(is_nan, code);
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, code);
        out.extend_from_slice(&[
            lanes[0] as u8,
            lanes[1] as u8,
            lanes[2] as u8,
            lanes[3] as u8,
            lanes[4] as u8,
            lanes[5] as u8,
            lanes[6] as u8,
            lanes[7] as u8,
        ]);
    }
    for &v in chunks.remainder() {
        out.push(quant_code(v, scale));
    }
}

// ---- int8 dequantize ---------------------------------------------------

/// `out[i] = (codes[i] as i8 as f32) * scale` over the zipped length
/// (`min(codes.len(), out.len())` — excess on either side is untouched,
/// mirroring the scalar `zip` loops). Exact across levels: i8 → f32 is
/// exact and the scale multiply is the same IEEE op lane-wise or not.
pub fn dequant_into(codes: &[u8], scale: f32, out: &mut [f32]) {
    dequant_into_at(level(), codes, scale, out)
}

pub fn dequant_into_scalar(codes: &[u8], scale: f32, out: &mut [f32]) {
    for (o, &b) in out.iter_mut().zip(codes) {
        *o = (b as i8) as f32 * scale;
    }
}

pub fn dequant_into_at(level: Level, codes: &[u8], scale: f32, out: &mut [f32]) {
    let n = codes.len().min(out.len());
    let (codes, out) = (&codes[..n], &mut out[..n]);
    #[cfg(target_arch = "x86_64")]
    match level {
        Level::Avx2 => return unsafe { dequant_avx2(codes, scale, out) },
        Level::Sse2 => return dequant_sse2(codes, scale, out),
        Level::Scalar => {}
    }
    let _ = level;
    dequant_into_scalar(codes, scale, out)
}

#[cfg(target_arch = "x86_64")]
fn dequant_sse2(codes: &[u8], scale: f32, out: &mut [f32]) {
    // SAFETY: SSE2 is part of the x86_64 baseline ABI.
    unsafe {
        use std::arch::x86_64::*;
        let s = _mm_set1_ps(scale);
        let zero = _mm_setzero_si128();
        let mut ci = codes.chunks_exact(4);
        let mut oi = out.chunks_exact_mut(4);
        for (c, o) in (&mut ci).zip(&mut oi) {
            let raw = i32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            let x = _mm_cvtsi32_si128(raw);
            // Sign-extend i8 → i32 with compares + unpacks (no imm-shift
            // intrinsics needed): the compare mask IS the sign byte/word.
            let s8 = _mm_cmpgt_epi8(zero, x);
            let w16 = _mm_unpacklo_epi8(x, s8);
            let s16 = _mm_cmpgt_epi16(zero, w16);
            let d32 = _mm_unpacklo_epi16(w16, s16);
            let v = _mm_mul_ps(_mm_cvtepi32_ps(d32), s);
            _mm_storeu_ps(o.as_mut_ptr(), v);
        }
        for (o, &b) in oi.into_remainder().iter_mut().zip(ci.remainder()) {
            *o = (b as i8) as f32 * scale;
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn dequant_avx2(codes: &[u8], scale: f32, out: &mut [f32]) {
    use std::arch::x86_64::*;
    let s = _mm256_set1_ps(scale);
    let mut ci = codes.chunks_exact(8);
    let mut oi = out.chunks_exact_mut(8);
    for (c, o) in (&mut ci).zip(&mut oi) {
        let b = _mm_loadl_epi64(c.as_ptr() as *const __m128i);
        let d32 = _mm256_cvtepi8_epi32(b);
        let v = _mm256_mul_ps(_mm256_cvtepi32_ps(d32), s);
        _mm256_storeu_ps(o.as_mut_ptr(), v);
    }
    for (o, &b) in oi.into_remainder().iter_mut().zip(ci.remainder()) {
        *o = (b as i8) as f32 * scale;
    }
}

// ---- sparse gather -----------------------------------------------------

/// `out.extend(indices.iter().map(|&i| src[i as usize]))` — the
/// values-at-indices gather of the Random-K path. The non-scalar form
/// hoists the bounds check to one vectorized max-prescan over the index
/// block and loads unchecked; an out-of-range index panics either way
/// (it is an internal invariant violation, not wire input).
pub fn gather_f32(src: &[f32], indices: &[u32], out: &mut Vec<f32>) {
    gather_f32_at(level(), src, indices, out)
}

pub fn gather_f32_scalar(src: &[f32], indices: &[u32], out: &mut Vec<f32>) {
    out.extend(indices.iter().map(|&i| src[i as usize]));
}

pub fn gather_f32_at(level: Level, src: &[f32], indices: &[u32], out: &mut Vec<f32>) {
    if level == Level::Scalar || indices.is_empty() {
        return gather_f32_scalar(src, indices, out);
    }
    let max = max_u32_at(level, indices);
    assert!(
        (max as usize) < src.len(),
        "gather index {max} out of range (len {})",
        src.len()
    );
    // SAFETY: every index is ≤ max < src.len() by the prescan above.
    out.extend(indices.iter().map(|&i| unsafe { *src.get_unchecked(i as usize) }));
}

fn max_u32_at(level: Level, xs: &[u32]) -> u32 {
    #[cfg(target_arch = "x86_64")]
    if level == Level::Avx2 {
        return unsafe { max_u32_avx2(xs) };
    }
    let _ = level;
    xs.iter().fold(0u32, |a, &i| a.max(i))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn max_u32_avx2(xs: &[u32]) -> u32 {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_si256();
    let mut chunks = xs.chunks_exact(8);
    for c in &mut chunks {
        let v = _mm256_loadu_si256(c.as_ptr() as *const __m256i);
        acc = _mm256_max_epu32(acc, v);
    }
    let mut lanes = [0u32; 8];
    _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
    let mut m = lanes.iter().fold(0u32, |a, &l| a.max(l));
    for &v in chunks.remainder() {
        m = m.max(v);
    }
    m
}

// ---- bulk little-endian moves ------------------------------------------

/// Bulk little-endian f32 append: on LE targets the in-memory layout IS
/// the wire layout, so the dispatched form is a single memcpy; the scalar
/// reference (and any BE target) writes per-element `to_le_bytes`.
pub fn extend_f32_le(out: &mut Vec<u8>, xs: &[f32]) {
    extend_f32_le_at(level(), out, xs)
}

pub fn extend_f32_le_scalar(out: &mut Vec<u8>, xs: &[f32]) {
    let start = out.len();
    out.resize(start + xs.len() * 4, 0);
    for (c, v) in out[start..].chunks_exact_mut(4).zip(xs) {
        c.copy_from_slice(&v.to_le_bytes());
    }
}

pub fn extend_f32_le_at(level: Level, out: &mut Vec<u8>, xs: &[f32]) {
    if level != Level::Scalar && cfg!(target_endian = "little") {
        // SAFETY: f32 has no padding and every bit pattern is valid to
        // read as bytes; u8 has alignment 1; lifetime bounded by xs.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        out.extend_from_slice(bytes);
    } else {
        extend_f32_le_scalar(out, xs);
    }
}

/// Bulk little-endian u32 append (see `extend_f32_le`).
pub fn extend_u32_le(out: &mut Vec<u8>, xs: &[u32]) {
    extend_u32_le_at(level(), out, xs)
}

pub fn extend_u32_le_scalar(out: &mut Vec<u8>, xs: &[u32]) {
    let start = out.len();
    out.resize(start + xs.len() * 4, 0);
    for (c, v) in out[start..].chunks_exact_mut(4).zip(xs) {
        c.copy_from_slice(&v.to_le_bytes());
    }
}

pub fn extend_u32_le_at(level: Level, out: &mut Vec<u8>, xs: &[u32]) {
    if level != Level::Scalar && cfg!(target_endian = "little") {
        // SAFETY: as `extend_f32_le_at`.
        let bytes =
            unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) };
        out.extend_from_slice(bytes);
    } else {
        extend_u32_le_scalar(out, xs);
    }
}

/// Little-endian bytes → f32, `min(dst.len(), src.len() / 4)` elements
/// (the dense decode path; excess on either side is untouched).
pub fn f32_from_le(src: &[u8], dst: &mut [f32]) {
    f32_from_le_at(level(), src, dst)
}

pub fn f32_from_le_scalar(src: &[u8], dst: &mut [f32]) {
    for (d, c) in dst.iter_mut().zip(src.chunks_exact(4)) {
        *d = f32::from_le_bytes(c.try_into().unwrap());
    }
}

pub fn f32_from_le_at(level: Level, src: &[u8], dst: &mut [f32]) {
    let n = dst.len().min(src.len() / 4);
    if level != Level::Scalar && cfg!(target_endian = "little") {
        // SAFETY: writing n*4 bytes into an f32 slice of length ≥ n; u8
        // reads are alignment-free and every bit pattern is a valid f32.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), dst.as_mut_ptr() as *mut u8, n * 4);
        }
    } else {
        f32_from_le_scalar(&src[..n * 4], &mut dst[..n]);
    }
}

// ---- sparse scatter decode ---------------------------------------------

/// A scatter decode rejected its (untrusted, wire-originated) input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScatterError {
    /// A sparse index points past the dense buffer.
    Index,
    /// A per-row scale offset points past the scales region.
    Scale,
}

// The `_view` kernels decode straight from borrowed little-endian wire
// bytes (the zero-copy OpDataView regions) and return `ScatterError` on
// corrupt input; the slice kernels serve the in-memory `decompress` paths
// and panic on violated internal invariants, exactly like the scalar
// indexing loops they replace. All of them process `BLOCK` indices at a
// time: one hoisted bounds check per block, SIMD value dequantization
// into a stack buffer, then in-order stores (duplicate index = last
// write wins, identical to the scalar loops). On the error path the
// scalar reference stops mid-element and the block kernels stop at a
// block boundary — both leave the dense buffer partially written, and
// every caller discards it on error.

/// Scatter f32 wire values at u32 wire indices into `dense`
/// (`dense[idx[k]] = vals[k]` over `min` pairs like the scalar zip).
pub fn scatter_f32_view(
    idx_le: &[u8],
    vals_le: &[u8],
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    scatter_f32_view_at(level(), idx_le, vals_le, dense)
}

pub fn scatter_f32_view_scalar(
    idx_le: &[u8],
    vals_le: &[u8],
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    let n = dense.len();
    for (ic, vc) in idx_le.chunks_exact(4).zip(vals_le.chunks_exact(4)) {
        let i = u32::from_le_bytes(ic.try_into().unwrap()) as usize;
        if i >= n {
            return Err(ScatterError::Index);
        }
        dense[i] = f32::from_le_bytes(vc.try_into().unwrap());
    }
    Ok(())
}

pub fn scatter_f32_view_at(
    level: Level,
    idx_le: &[u8],
    vals_le: &[u8],
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    if level == Level::Scalar {
        return scatter_f32_view_scalar(idx_le, vals_le, dense);
    }
    let n = dense.len();
    let pairs = (idx_le.len() / 4).min(vals_le.len() / 4);
    let mut idx = [0u32; BLOCK];
    let mut vals = [0.0f32; BLOCK];
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        read_idx_block(&idx_le[done * 4..(done + m) * 4], &mut idx[..m]);
        if (block_max(level, &idx[..m]) as usize) >= n {
            return Err(ScatterError::Index);
        }
        f32_from_le_at(level, &vals_le[done * 4..(done + m) * 4], &mut vals[..m]);
        // SAFETY: every index in this block was just checked < n.
        for (&i, &x) in idx[..m].iter().zip(&vals[..m]) {
            unsafe { *dense.get_unchecked_mut(i as usize) = x };
        }
        done += m;
    }
    Ok(())
}

/// Scatter int8 codes at u32 wire indices with one per-message scale
/// (`dense[idx[k]] = (codes[k] as i8 as f32) * scale` over `min` pairs).
pub fn scatter_int8_view(
    idx_le: &[u8],
    codes: &[u8],
    scale: f32,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    scatter_int8_view_at(level(), idx_le, codes, scale, dense)
}

pub fn scatter_int8_view_scalar(
    idx_le: &[u8],
    codes: &[u8],
    scale: f32,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    let n = dense.len();
    for (ic, &b) in idx_le.chunks_exact(4).zip(codes) {
        let i = u32::from_le_bytes(ic.try_into().unwrap()) as usize;
        if i >= n {
            return Err(ScatterError::Index);
        }
        dense[i] = (b as i8) as f32 * scale;
    }
    Ok(())
}

pub fn scatter_int8_view_at(
    level: Level,
    idx_le: &[u8],
    codes: &[u8],
    scale: f32,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    if level == Level::Scalar {
        return scatter_int8_view_scalar(idx_le, codes, scale, dense);
    }
    let n = dense.len();
    let pairs = (idx_le.len() / 4).min(codes.len());
    let mut idx = [0u32; BLOCK];
    let mut vals = [0.0f32; BLOCK];
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        read_idx_block(&idx_le[done * 4..(done + m) * 4], &mut idx[..m]);
        if (block_max(level, &idx[..m]) as usize) >= n {
            return Err(ScatterError::Index);
        }
        dequant_into_at(level, &codes[done..done + m], scale, &mut vals[..m]);
        // SAFETY: every index in this block was just checked < n.
        for (&i, &x) in idx[..m].iter().zip(&vals[..m]) {
            unsafe { *dense.get_unchecked_mut(i as usize) = x };
        }
        done += m;
    }
    Ok(())
}

/// Scatter int8 codes at u32 wire indices with per-row scales read from
/// the little-endian scales region (`scale = scales_le[(i / chunk) * 4..]`).
pub fn scatter_int8_rows_view(
    idx_le: &[u8],
    codes: &[u8],
    scales_le: &[u8],
    chunk: usize,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    scatter_int8_rows_view_at(level(), idx_le, codes, scales_le, chunk, dense)
}

pub fn scatter_int8_rows_view_scalar(
    idx_le: &[u8],
    codes: &[u8],
    scales_le: &[u8],
    chunk: usize,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    let n = dense.len();
    let chunk = chunk.max(1);
    for (ic, &b) in idx_le.chunks_exact(4).zip(codes) {
        let i = u32::from_le_bytes(ic.try_into().unwrap()) as usize;
        if i >= n {
            return Err(ScatterError::Index);
        }
        let off = (i / chunk) * 4;
        let s = scales_le.get(off..off + 4).ok_or(ScatterError::Scale)?;
        dense[i] = (b as i8) as f32 * f32::from_le_bytes(s.try_into().unwrap());
    }
    Ok(())
}

pub fn scatter_int8_rows_view_at(
    level: Level,
    idx_le: &[u8],
    codes: &[u8],
    scales_le: &[u8],
    chunk: usize,
    dense: &mut [f32],
) -> Result<(), ScatterError> {
    if level == Level::Scalar {
        return scatter_int8_rows_view_scalar(idx_le, codes, scales_le, chunk, dense);
    }
    let n = dense.len();
    let chunk = chunk.max(1);
    let pairs = (idx_le.len() / 4).min(codes.len());
    let mut idx = [0u32; BLOCK];
    let mut vals = [0.0f32; BLOCK];
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        read_idx_block(&idx_le[done * 4..(done + m) * 4], &mut idx[..m]);
        if (block_max(level, &idx[..m]) as usize) >= n {
            return Err(ScatterError::Index);
        }
        // Dequantize runs of same-row indices with their scale splatted
        // (Top-K support is index-sorted, so runs span whole rows; the
        // run loop is still correct for arbitrary index order).
        let mut s = 0usize;
        while s < m {
            let row = idx[s] as usize / chunk;
            let mut e = s + 1;
            while e < m && idx[e] as usize / chunk == row {
                e += 1;
            }
            let off = row * 4;
            let sb = scales_le.get(off..off + 4).ok_or(ScatterError::Scale)?;
            let scale = f32::from_le_bytes(sb.try_into().unwrap());
            dequant_into_at(level, &codes[done + s..done + e], scale, &mut vals[..e - s]);
            // SAFETY: every index in this block was checked < n above.
            for (&i, &x) in idx[s..e].iter().zip(&vals[..e - s]) {
                unsafe { *dense.get_unchecked_mut(i as usize) = x };
            }
            s = e;
        }
        done += m;
    }
    Ok(())
}

/// In-memory f32 scatter (`dense[idx[k]] = vals[k]` over `min` pairs) —
/// the `decompress` hot loop. Panics on an out-of-range index like the
/// scalar indexing loop it replaces.
pub fn scatter_f32(indices: &[u32], vals: &[f32], dense: &mut [f32]) {
    scatter_f32_mem_at(level(), indices, vals, dense)
}

pub fn scatter_f32_mem_scalar(indices: &[u32], vals: &[f32], dense: &mut [f32]) {
    for (&i, &v) in indices.iter().zip(vals) {
        dense[i as usize] = v;
    }
}

pub fn scatter_f32_mem_at(level: Level, indices: &[u32], vals: &[f32], dense: &mut [f32]) {
    if level == Level::Scalar {
        return scatter_f32_mem_scalar(indices, vals, dense);
    }
    let n = dense.len();
    let pairs = indices.len().min(vals.len());
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        let idx = &indices[done..done + m];
        let max = block_max(level, idx);
        assert!((max as usize) < n, "scatter index {max} out of range (len {n})");
        // SAFETY: every index in this block was just checked < n.
        for (&i, &x) in idx.iter().zip(&vals[done..done + m]) {
            unsafe { *dense.get_unchecked_mut(i as usize) = x };
        }
        done += m;
    }
}

/// In-memory int8 scatter with one scale (the `QSparse` decompress).
pub fn scatter_int8(indices: &[u32], codes: &[u8], scale: f32, dense: &mut [f32]) {
    scatter_int8_mem_at(level(), indices, codes, scale, dense)
}

pub fn scatter_int8_mem_scalar(indices: &[u32], codes: &[u8], scale: f32, dense: &mut [f32]) {
    for (&i, &b) in indices.iter().zip(codes) {
        dense[i as usize] = (b as i8) as f32 * scale;
    }
}

pub fn scatter_int8_mem_at(
    level: Level,
    indices: &[u32],
    codes: &[u8],
    scale: f32,
    dense: &mut [f32],
) {
    if level == Level::Scalar {
        return scatter_int8_mem_scalar(indices, codes, scale, dense);
    }
    let n = dense.len();
    let pairs = indices.len().min(codes.len());
    let mut vals = [0.0f32; BLOCK];
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        let idx = &indices[done..done + m];
        let max = block_max(level, idx);
        assert!((max as usize) < n, "scatter index {max} out of range (len {n})");
        dequant_into_at(level, &codes[done..done + m], scale, &mut vals[..m]);
        // SAFETY: every index in this block was just checked < n.
        for (&i, &x) in idx.iter().zip(&vals[..m]) {
            unsafe { *dense.get_unchecked_mut(i as usize) = x };
        }
        done += m;
    }
}

/// In-memory int8 scatter with per-row scales (the `QSparseRows`
/// decompress; `scales[i / chunk]` panics when missing, like the scalar
/// indexing loop).
pub fn scatter_int8_rows(
    indices: &[u32],
    codes: &[u8],
    scales: &[f32],
    chunk: usize,
    dense: &mut [f32],
) {
    scatter_int8_rows_mem_at(level(), indices, codes, scales, chunk, dense)
}

pub fn scatter_int8_rows_mem_scalar(
    indices: &[u32],
    codes: &[u8],
    scales: &[f32],
    chunk: usize,
    dense: &mut [f32],
) {
    let chunk = chunk.max(1);
    for (&i, &b) in indices.iter().zip(codes) {
        dense[i as usize] = (b as i8) as f32 * scales[i as usize / chunk];
    }
}

pub fn scatter_int8_rows_mem_at(
    level: Level,
    indices: &[u32],
    codes: &[u8],
    scales: &[f32],
    chunk: usize,
    dense: &mut [f32],
) {
    if level == Level::Scalar {
        return scatter_int8_rows_mem_scalar(indices, codes, scales, chunk, dense);
    }
    let n = dense.len();
    let chunk = chunk.max(1);
    let pairs = indices.len().min(codes.len());
    let mut vals = [0.0f32; BLOCK];
    let mut done = 0usize;
    while done < pairs {
        let m = BLOCK.min(pairs - done);
        let idx = &indices[done..done + m];
        let max = block_max(level, idx);
        assert!((max as usize) < n, "scatter index {max} out of range (len {n})");
        let mut s = 0usize;
        while s < m {
            let row = idx[s] as usize / chunk;
            let mut e = s + 1;
            while e < m && idx[e] as usize / chunk == row {
                e += 1;
            }
            dequant_into_at(level, &codes[done + s..done + e], scales[row], &mut vals[..e - s]);
            // SAFETY: every index in this block was checked < n above.
            for (&i, &x) in idx[s..e].iter().zip(&vals[..e - s]) {
                unsafe { *dense.get_unchecked_mut(i as usize) = x };
            }
            s = e;
        }
        done += m;
    }
}

/// Decode a block of little-endian u32 indices (`src.len() == buf.len()*4`).
fn read_idx_block(src: &[u8], buf: &mut [u32]) {
    if cfg!(target_endian = "little") {
        // SAFETY: copying src.len() bytes into a u32 buffer of length
        // src.len()/4; unaligned source reads via byte copy.
        unsafe {
            std::ptr::copy_nonoverlapping(src.as_ptr(), buf.as_mut_ptr() as *mut u8, src.len());
        }
    } else {
        for (b, c) in buf.iter_mut().zip(src.chunks_exact(4)) {
            *b = u32::from_le_bytes(c.try_into().unwrap());
        }
    }
}

/// Max over a (≤ BLOCK) index block — the hoisted bounds check.
fn block_max(level: Level, idx: &[u32]) -> u32 {
    max_u32_at(level, idx)
}
