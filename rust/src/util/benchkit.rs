//! Tiny benchmark harness (criterion is unavailable offline): warmup +
//! N timed iterations, reporting min/median/mean.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min_s: f64,
    pub median_s: f64,
    pub mean_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10}/iter (min {:>10}, n={})",
            self.name,
            crate::util::math::fmt_secs(self.median_s),
            crate::util::math::fmt_secs(self.min_s),
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs. Returns stats.
/// `f` should return something observable to keep the optimizer honest.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchResult {
        name: name.to_string(),
        iters,
        min_s: samples[0],
        median_s: samples[samples.len() / 2],
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 11, || {
            (0..1000).map(|i| i * i).sum::<usize>()
        });
        assert!(r.min_s >= 0.0);
        assert!(r.median_s >= r.min_s);
        assert_eq!(r.iters, 11);
        assert!(r.line().contains("noop-ish"));
    }
}
