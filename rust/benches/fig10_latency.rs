//! Bench target for Fig. 10: averaged one-iteration training latency per
//! testbed × scheduler × compressor (ratio 100), for all three Table-6
//! workloads (ResNet18, ResNet101, GPT2-XL), via the discrete-event
//! simulator.
//!
//! The paper's qualitative shape to reproduce:
//!   - equal-number is the slowest scheduling policy;
//!   - equal-compute helps only a little (communication dominates);
//!   - OP-Fence wins clearly;
//!   - compression (topk/adatopk) slashes latency, uniform ≤ adatopk but
//!     with no large gap;
//!   - overall best-vs-baseline speedup lands in the 1.45–9.39x band.

use fusionllm::cluster::testbed;
use fusionllm::compress::{CompressKind, CompressPlan};
use fusionllm::cost::throughput::PipelineParams;
use fusionllm::opdag::builders::{
    resnet_chain, transformer_chain, ResNetSpec, TransformerSpec,
};
use fusionllm::opdag::Dag;
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler;
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::util::math::fmt_secs;

fn workloads() -> Vec<(&'static str, Dag, usize)> {
    vec![
        ("ResNet18", resnet_chain(&ResNetSpec::resnet18()), 5),
        ("ResNet101", resnet_chain(&ResNetSpec::resnet101()), 5),
        ("GPT2-XL", transformer_chain(&TransformerSpec::gpt2_xl()), 2),
    ]
}

fn main() {
    let schedulers = ["equal-number", "equal-compute", "opfence"];
    let compressors = [CompressKind::None, CompressKind::TopK, CompressKind::AdaTopK];
    let ratio = 100.0;

    let mut band_min = f64::MAX;
    let mut band_max: f64 = 0.0;
    for tb_id in [1usize, 2] {
        let tb = testbed::by_id(tb_id, 1);
        for (wname, dag, n_micro) in workloads() {
            println!(
                "\n=== Fig. 10 — testbed {tb_id}, {wname}, ratio {ratio}, n_micro {n_micro} ==="
            );
            println!(
                "{:<14} {:>12} {:>12} {:>12}",
                "scheduler", "dense", "topk", "adatopk"
            );
            let params =
                PipelineParams { n_micro, micro_size: 3, include_bwd: true };
            let mut matrix = Vec::new();
            for s in schedulers {
                let part = scheduler::by_name(s).unwrap().schedule(&dag, &tb).unwrap();
                let sp = StagePlan::from_partition(&dag, &part, &tb);
                let sched =
                    PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), n_micro);
                let mut row = Vec::new();
                for kind in compressors {
                    let plan = match kind {
                        CompressKind::None => CompressPlan::dense(tb.nodes.len()),
                        CompressKind::AdaTopK => {
                            CompressPlan::adatopk(&dag, &part, &tb, params, ratio)
                        }
                        k => CompressPlan::uniform(k, ratio, tb.nodes.len()),
                    };
                    row.push(simulate_iteration(&sp, &tb, &sched, &plan).iter_s);
                }
                println!(
                    "{:<14} {:>12} {:>12} {:>12}",
                    s,
                    fmt_secs(row[0]),
                    fmt_secs(row[1]),
                    fmt_secs(row[2])
                );
                matrix.push(row);
            }
            // Paper shape assertions.
            let eq_num_dense = matrix[0][0];
            let opfence_dense = matrix[2][0];
            let opfence_ada = matrix[2][2];
            assert!(
                opfence_dense <= eq_num_dense * 1.001,
                "{wname}: opfence not better than equal-number"
            );
            assert!(opfence_ada < opfence_dense, "{wname}: adatopk not faster");
            let speedup = eq_num_dense / opfence_ada;
            println!("best combo speedup vs equal-number dense: {speedup:.2}x");
            band_min = band_min.min(speedup);
            band_max = band_max.max(speedup);
        }
    }
    println!(
        "\nspeedup band across testbeds/workloads: {band_min:.2}x – {band_max:.2}x \
         (paper: 1.45 – 9.39x)"
    );
}
