//! Bench target for Fig. 11: compression ratio 100 vs 1000.
//!
//! Paper finding: ratio 1000 is NOT ~10x faster than ratio 100 — at high
//! ratios the per-message latency term α (and scheduling overhead)
//! dominates, so returns diminish sharply.

use fusionllm::cluster::testbed;
use fusionllm::compress::{CompressKind, CompressPlan};
use fusionllm::cost::throughput::PipelineParams;
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler;
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::util::math::fmt_secs;

fn main() {
    let n_micro = 2;
    println!("=== Fig. 11 — GPT2-XL, OP-Fence, uniform TopK at ratio 100 vs 1000 ===");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>18}",
        "testbed", "dense", "ratio 100", "ratio 1000", "1000-vs-100 gain"
    );
    for tb_id in [1usize, 2] {
        let tb = testbed::by_id(tb_id, 1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = scheduler::by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let sp = StagePlan::from_partition(&dag, &part, &tb);
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), n_micro);
        let run = |plan: &CompressPlan| simulate_iteration(&sp, &tb, &sched, plan).iter_s;
        let dense = run(&CompressPlan::dense(tb.nodes.len()));
        let r100 = run(&CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len()));
        let r1000 =
            run(&CompressPlan::uniform(CompressKind::TopK, 1000.0, tb.nodes.len()));
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>17.2}x",
            format!("testbed{tb_id}"),
            fmt_secs(dense),
            fmt_secs(r100),
            fmt_secs(r1000),
            r100 / r1000
        );
        // Paper shape: nowhere near the nominal 10x.
        assert!(r1000 <= r100);
        assert!(
            r100 / r1000 < 5.0,
            "ratio-1000 gain {:.2} should be << 10x (α-dominated)",
            r100 / r1000
        );
    }
    println!("\nshape check passed: 10x more compression buys far less than 10x");
    println!("latency (per-message α dominates), matching the paper's Fig. 11.");
}
