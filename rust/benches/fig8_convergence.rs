//! Bench target for Fig. 8 (short run): loss curves for dense vs uniform
//! TopK vs AdaTopK on the tiny config. Full curves: examples/convergence_fig8.
//! Requires `make artifacts`; skips cleanly when absent.

use fusionllm::broker::{self, Job};
use fusionllm::compress::CompressKind;

fn main() {
    let probe = Job::default();
    if !probe.artifacts_root.join("tiny/manifest.json").exists() {
        println!("fig8_convergence: artifacts missing — run `make artifacts` (skipping)");
        return;
    }
    let steps = 40;
    println!("=== Fig. 8 (short) — tiny config, ratio 50, {steps} steps ===");
    println!(
        "{:<10} {:>12} {:>12} {:>10}",
        "variant", "first-5 loss", "last-5 loss", "Δ"
    );
    let mut finals = Vec::new();
    for kind in [CompressKind::None, CompressKind::TopK, CompressKind::AdaTopK] {
        let job = Job {
            iters: steps,
            lr: 0.1,
            compress: kind,
            ratio: 50.0,
            ..Job::default()
        };
        let r = broker::run(&job).expect("training run");
        let first: f32 = r.losses.iter().take(5).sum::<f32>() / 5.0;
        let last: f32 = r.losses.iter().rev().take(5).sum::<f32>() / 5.0;
        println!(
            "{:<10} {:>12.4} {:>12.4} {:>+10.4}",
            kind.name(),
            first,
            last,
            last - first
        );
        finals.push((kind, last));
    }
    // Shape: every variant converges; AdaTopK within a whisker of dense.
    let dense = finals[0].1;
    let ada = finals[2].1;
    assert!(ada < finals[0].1 + 0.6, "adatopk diverged: {ada} vs dense {dense}");
    println!("\nconvergence shape OK (full-length curves: examples/convergence_fig8)");
}
