//! Bench target for Table 1: GPU economics of pre-training GPT-3.
//! Regenerates the table the paper prints (GPU days, #GPUs to load).

use fusionllm::cluster::compnode::{gpu_days_for_gpt3, gpus_to_load_gpt3, GpuModel};

fn main() {
    println!("=== Table 1: pre-train GPT-3 (3.14e23 FLOPs, 175B params) ===");
    println!(
        "{:<10} {:>9} {:>8} {:>9} {:>8} {:>8}",
        "GPU", "price $", "TFLOPS", "GPU days", "mem GB", "# GPUs"
    );
    let rows = [
        (GpuModel::H100, 4807.0),
        (GpuModel::A100, 11654.0),
        (GpuModel::Rtx4090, 22004.0),
        (GpuModel::Rtx4080, 37274.0),
        (GpuModel::Rtx3080, 61079.0),
    ];
    for (gpu, paper_days) in rows {
        let days = gpu_days_for_gpt3(gpu);
        println!(
            "{:<10} {:>9.0} {:>8.2} {:>9.0} {:>8} {:>8}",
            gpu.name(),
            gpu.price_usd(),
            gpu.peak_tflops(),
            days,
            gpu.memory_bytes() >> 30,
            gpus_to_load_gpt3(gpu),
        );
        let rel = (days - paper_days).abs() / paper_days;
        assert!(
            rel < 0.02 || gpu == GpuModel::A100,
            "{}: {days:.0} vs paper {paper_days}",
            gpu.name()
        );
        // Paper's A100 row (23308 days) is internally inconsistent with its
        // own TFLOPS column (3.14e23 / 311.84e12 / 86400 = 11654); we print
        // the formula-true value and note the discrepancy.
    }
    println!("\nnote: the paper's A100 'GPU days' entry (23308) does not match");
    println!("its own TFLOPS column; we reproduce the formula (11654).");
    println!("paper-vs-ours recorded in EXPERIMENTS.md §Table-1.");
}
