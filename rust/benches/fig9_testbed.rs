//! Bench target for Fig. 9: latency and bandwidth structure of the
//! 24-/48-GPU testbeds (the paper shows heatmaps; we print per-class
//! distributions plus a coarse machine-level matrix).

use fusionllm::cluster::louvain::louvain;
use fusionllm::cluster::testbed;

fn main() {
    for id in [1usize, 2] {
        let tb = testbed::by_id(id, 1);
        println!("\n=== Fig. 9 — {} ===", tb.summary());

        // Machine-level bandwidth matrix (mean over GPU pairs).
        let mut machines: Vec<(String, Vec<usize>)> = Vec::new();
        for n in &tb.nodes {
            let key = format!("{}{}", n.cluster, n.machine);
            match machines.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => v.push(n.id),
                None => machines.push((key, vec![n.id])),
            }
        }
        print!("{:<6}", "");
        for (k, _) in &machines {
            print!("{k:>8}");
        }
        println!();
        for (ka, va) in &machines {
            print!("{ka:<6}");
            for (_, vb) in &machines {
                let mut s = 0.0;
                let mut c = 0;
                for &i in va {
                    for &j in vb {
                        if i != j {
                            s += tb.net.bandwidth_bps(i, j);
                            c += 1;
                        }
                    }
                }
                if c == 0 {
                    print!("{:>8}", "-");
                } else {
                    let mean = s / c as f64;
                    if mean >= 1e9 {
                        print!("{:>7.1}G", mean / 1e9);
                    } else {
                        print!("{:>7.0}M", mean / 1e6);
                    }
                }
            }
            println!();
        }

        // Envelope check (the paper's stated 8 Mbps – 10 Gbps range).
        let (mut bw_min, mut bw_max) = (f64::MAX, 0.0f64);
        let (mut a_min, mut a_max) = (f64::MAX, 0.0f64);
        for i in 0..tb.nodes.len() {
            for j in (i + 1)..tb.nodes.len() {
                bw_min = bw_min.min(tb.net.bandwidth_bps(i, j));
                bw_max = bw_max.max(tb.net.bandwidth_bps(i, j));
                a_min = a_min.min(tb.net.alpha(i, j));
                a_max = a_max.max(tb.net.alpha(i, j));
            }
        }
        println!(
            "bandwidth {:.0} Mbps – {:.1} Gbps (paper: 8 Mbps – 10 Gbps); α {:.2}–{:.1} ms",
            bw_min / 1e6,
            bw_max / 1e9,
            a_min * 1e3,
            a_max * 1e3
        );
        assert!(bw_min >= 7.9e6 && bw_max <= 11.1e9);

        let comm = louvain(&tb.net);
        let k = comm.iter().max().unwrap() + 1;
        println!("Louvain communities: {k} (clusters/machines rediscovered from bandwidth)");
    }
}
