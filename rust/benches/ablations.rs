//! Design ablations (DESIGN.md §Deviations item 5 + schedule/fault studies):
//!   A. OP-Fence boundary refinement on/off
//!   B. OP-Fence greedy vs DP split
//!   C. GPipe vs 1F1B simulated latency + activation stash
//!   D. iteration latency under packet loss (paper §8), dense vs adatopk
//!   E. radix-select vs quickselect Top-K threshold

use fusionllm::cluster::testbed;
use fusionllm::compress::CompressPlan;
use fusionllm::cost::throughput::PipelineParams;
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler::opfence::OpFence;
use fusionllm::scheduler::Scheduler;
use fusionllm::simnet::{simulate_iteration, simulate_iteration_faulty, FaultModel, StagePlan};
use fusionllm::util::benchkit::bench;
use fusionllm::util::math::{fmt_secs, kth_largest_abs, kth_largest_abs_quickselect};
use fusionllm::util::rng::Rng;

fn main() {
    let tb = testbed::testbed1(1);
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let n_micro = 2;
    let params = PipelineParams { n_micro, micro_size: 3, include_bwd: true };
    let sim = |part: &fusionllm::opdag::Partition, plan: &CompressPlan, kind: ScheduleKind| {
        let sp = StagePlan::from_partition(&dag, part, &tb);
        let sched = PipelineSchedule::new(kind, sp.n_stages(), n_micro);
        simulate_iteration(&sp, &tb, &sched, plan).iter_s
    };
    let dense = CompressPlan::dense(tb.nodes.len());

    println!("=== A. OP-Fence boundary refinement (GPT2-XL, testbed 1, dense) ===");
    let p_off = OpFence { refine_boundaries: false, ..Default::default() }
        .schedule(&dag, &tb)
        .unwrap();
    let p_on = OpFence::default().schedule(&dag, &tb).unwrap();
    let (t_off, t_on) = (sim(&p_off, &dense, ScheduleKind::GPipe), sim(&p_on, &dense, ScheduleKind::GPipe));
    println!("refine=off {}   refine=on {}   gain {:.2}x", fmt_secs(t_off), fmt_secs(t_on), t_off / t_on);
    assert!(t_on <= t_off * 1.001);

    println!("\n=== B. greedy vs DP split ===");
    let p_dp = OpFence { use_dp: true, ..Default::default() }.schedule(&dag, &tb).unwrap();
    let t_dp = sim(&p_dp, &dense, ScheduleKind::GPipe);
    println!("greedy {}   dp {}   ratio {:.2}", fmt_secs(t_on), fmt_secs(t_dp), t_on / t_dp);

    println!("\n=== C. GPipe vs 1F1B (n_micro 8) ===");
    let sp = StagePlan::from_partition(&dag, &p_on, &tb);
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        let sched = PipelineSchedule::new(kind, sp.n_stages(), 8);
        let r = simulate_iteration(&sp, &tb, &sched, &dense);
        println!(
            "{kind:?}: iter {}  bubble {:.1}%  peak stash(stage0) {}",
            fmt_secs(r.iter_s),
            100.0 * r.bubble_frac,
            sched.peak_stash(0)
        );
    }

    println!("\n=== D. packet loss (paper §8), dense vs adatopk ratio 100 ===");
    let ada = CompressPlan::adatopk(&dag, &p_on, &tb, params, 100.0);
    let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), n_micro);
    println!("{:<8} {:>12} {:>12}", "loss", "dense", "adatopk");
    for p in [0.0, 0.05, 0.2] {
        let f = FaultModel { loss_prob: p, rto_s: 0.2, seed: 11 };
        let td = simulate_iteration_faulty(&sp, &tb, &sched, &dense, f).iter_s;
        let ta = simulate_iteration_faulty(&sp, &tb, &sched, &ada, f).iter_s;
        println!("{:<8} {:>12} {:>12}", format!("{:.0}%", p * 100.0), fmt_secs(td), fmt_secs(ta));
    }

    println!("\n=== E. Top-K threshold: radix vs quickselect (19.66 MB) ===");
    let mut rng = Rng::new(3);
    let xs: Vec<f32> = (0..3 * 1024 * 1600).map(|_| rng.f32() - 0.5).collect();
    let k = xs.len() / 100;
    let r1 = bench("radix select", 1, 7, || kth_largest_abs(&xs, k));
    let r2 = bench("quickselect", 1, 7, || kth_largest_abs_quickselect(&xs, k));
    println!("{}", r1.line());
    println!("{}", r2.line());
    println!("speedup {:.1}x", r2.median_s / r1.median_s);
}
