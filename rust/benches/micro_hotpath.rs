//! Hot-path microbenchmarks (§Perf, L3): the operations on the per-message
//! critical path of the coordinator, measured with the offline benchkit.
//!
//!   * Top-K wire compression of a GPT2-XL-sized activation (19.66 MB)
//!   * OP-Data encode/decode round trip
//!   * discrete-event iteration simulation (48 devices)
//!   * Louvain + OP-Fence scheduling (48 devices)

use fusionllm::cluster::testbed;
use fusionllm::compress::{CompressPlan, Compressor, TopK};
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::opdag::data::{OpData, OpDataKind};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler::{self, Scheduler};
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::util::benchkit::bench;
use fusionllm::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    // GPT2-XL inter-stage activation: 3*1024*1600 f32 = 19.66 MB.
    let act: Vec<f32> = (0..3 * 1024 * 1600).map(|_| rng.f32() - 0.5).collect();

    let topk = TopK { ratio: 100.0 };
    let r = bench("topk compress 19.66MB (ratio 100)", 2, 10, || topk.compress(&act));
    println!("{}", r.line());
    let tput = act.len() as f64 * 4.0 / r.median_s / 1e9;
    println!("{:<40} {tput:>9.2} GB/s", "  -> effective throughput");

    let c = topk.compress(&act);
    let mut dense = vec![0.0f32; act.len()];
    let r = bench("topk decompress", 2, 10, || {
        topk.decompress(&c, &mut dense);
        dense[0]
    });
    println!("{}", r.line());

    let mut od = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, c.values.clone());
    od.indices = c.indices.clone();
    od.compress = c.cfg.clone();
    let r = bench("OpData encode (sparse 196k keep)", 2, 20, || od.encode());
    println!("{}", r.line());
    let buf = od.encode();
    let r = bench("OpData decode", 2, 20, || OpData::decode(&buf).unwrap());
    println!("{}", r.line());

    let tb = testbed::testbed2(1);
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let r = bench("OP-Fence schedule (48 devices)", 1, 10, || {
        scheduler::opfence::OpFence::default().schedule(&dag, &tb).unwrap()
    });
    println!("{}", r.line());

    let part = scheduler::by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
    let sp = StagePlan::from_partition(&dag, &part, &tb);
    let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), 8);
    let plan = CompressPlan::dense(tb.nodes.len());
    let r = bench("simnet iteration (48 stages, nb=8)", 2, 50, || {
        simulate_iteration(&sp, &tb, &sched, &plan).iter_s
    });
    println!("{}", r.line());

    println!("\n(record before/after in EXPERIMENTS.md §Perf)");
}
