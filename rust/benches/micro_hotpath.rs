//! Hot-path microbenchmarks (§Perf, L3): the operations on the per-message
//! critical path of the coordinator, measured with the offline benchkit.
//!
//!   * Top-K wire compression of a GPT2-XL-sized activation (19.66 MB),
//!     both the allocating API and the steady-state `compress_into` path
//!   * int8 quantize/dequantize of the same payload, and the combined
//!     int8+Top-K path (select + quantize + per-row scales) — the ~5
//!     B/kept-value wire encoding
//!   * OP-Data encode/decode round trip (bulk codec + zero-copy view)
//!   * discrete-event iteration simulation (48 devices)
//!   * Louvain + OP-Fence scheduling (48 devices)
//!
//! Besides the human-readable table, results are emitted to
//! `BENCH_micro_hotpath.json` at the repo root (op -> median_s / GB/s) so
//! the perf trajectory is tracked across PRs (EXPERIMENTS.md §Perf).

use fusionllm::compress::{
    ChunkedTopK, CompressKind, CompressPlan, CompressScratch, Compressed, Compressor,
    Int8Quantizer, Quantized, TopK,
};
use fusionllm::cluster::testbed;
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::opdag::data::{CompressCfg, OpData, OpDataKind, OpDataView};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler::{self, Scheduler};
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::transport::frame::{encode_frame, FrameKind, Framer, Lane};
use fusionllm::transport::{chan, PacketPool};
use fusionllm::util::benchkit::{bench, BenchResult};
use fusionllm::util::fnv;
use fusionllm::util::json::{n, obj, Json};
use fusionllm::util::simd;
use fusionllm::util::math::compress_threads;
use fusionllm::util::rng::Rng;
use fusionllm::worker::{
    run_schedule_with, LinkEncoder, NullBackend, RunOpts, StageCodec, StageLinks, Wire,
};
use std::sync::mpsc::channel;

fn main() {
    let mut results: Vec<(BenchResult, f64)> = Vec::new();
    let mut run = |r: BenchResult, bytes: f64| {
        println!("{}", r.line());
        if bytes > 0.0 {
            let tput = bytes / r.median_s / 1e9;
            println!("{:<40} {tput:>9.2} GB/s", "  -> effective throughput");
        }
        results.push((r, bytes));
    };

    let mut rng = Rng::new(7);
    // GPT2-XL inter-stage activation: 3*1024*1600 f32 = 19.66 MB.
    let act: Vec<f32> = (0..3 * 1024 * 1600).map(|_| rng.f32() - 0.5).collect();
    let act_bytes = act.len() as f64 * 4.0;
    println!("compress worker threads: {}\n", compress_threads());

    let topk = TopK { ratio: 100.0 };
    let r = bench("topk compress 19.66MB (ratio 100)", 2, 10, || topk.compress(&act));
    run(r, act_bytes);

    // Steady state: per-link scratch + reused Compressed, zero alloc/msg.
    let mut scratch = CompressScratch::default();
    let mut comp = Compressed::default();
    let r = bench("topk compress_into (steady-state)", 2, 10, || {
        topk.compress_with(&act, &mut comp, &mut scratch);
        comp.values.len()
    });
    run(r, act_bytes);

    let c = topk.compress(&act);
    let mut dense = vec![0.0f32; act.len()];
    let r = bench("topk decompress", 2, 10, || {
        topk.decompress(&c, &mut dense);
        dense[0]
    });
    run(r, act_bytes);

    // int8 value codec: dense quantize/dequantize, then the combined
    // int8+Top-K path the LinkEncoder runs under `--wire-codec int8`
    // (ChunkedTopK select + per-row scale quantization, ~5 B/kept value).
    let r = bench("int8 quantize 19.66MB (dense)", 2, 10, || {
        Int8Quantizer.compress_with(&act, &mut comp, &mut scratch);
        comp.bytes.len()
    });
    run(r, act_bytes);

    let cq = Int8Quantizer.compress(&act);
    let r = bench("int8 dequantize 19.66MB", 2, 10, || {
        Int8Quantizer.decompress(&cq, &mut dense);
        dense[0]
    });
    run(r, act_bytes);

    let combined = Quantized::per_row(ChunkedTopK { ratio: 100.0, chunk: 1600 }, 1600);
    let r = bench("int8+topk compress_into (combined)", 2, 10, || {
        combined.compress_with(&act, &mut comp, &mut scratch);
        comp.bytes.len()
    });
    run(r, act_bytes);

    let cc = combined.compress(&act);
    println!(
        "{:<40} {:>9.2} B/value",
        "  -> combined encoded payload",
        cc.wire_bytes() / cc.indices.len() as f64
    );
    let r = bench("int8+topk decompress (combined)", 2, 10, || {
        combined.decompress(&cc, &mut dense);
        dense[0]
    });
    run(r, act_bytes);

    let mut od = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, c.values.clone());
    od.indices = c.indices.clone();
    od.compress = c.cfg.clone();
    let msg_bytes = (od.payload.len() * 4 + od.indices.len() * 4 + 64) as f64;
    let r = bench("OpData encode (sparse 196k keep)", 2, 20, || od.encode());
    run(r, msg_bytes);

    let mut wire = Vec::new();
    let r = bench("OpData encode_into (reused buf)", 2, 20, || {
        od.encode_into(&mut wire);
        wire.len()
    });
    run(r, msg_bytes);

    let buf = od.encode();
    let r = bench("OpData decode", 2, 20, || OpData::decode(&buf).unwrap());
    run(r, msg_bytes);

    let r = bench("OpDataView parse (zero-copy)", 2, 20, || {
        let v = OpDataView::parse(&buf).unwrap();
        v.payload_len()
    });
    run(r, msg_bytes);

    // u24 delta-coded sparse indices (`--wire-codec int8-u24`): the same
    // sparse message with the index region packed first-absolute +
    // u24 deltas — 3 B/index on the wire instead of 4, unpacked on the
    // fly by the zero-copy view.
    let mut idx24 = c.indices.clone();
    idx24.sort_unstable();
    let mut od24 = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, c.values.clone());
    od24.indices = idx24;
    od24.compress = CompressCfg::QSparseRowsDelta {
        ratio: 100.0,
        total_len: act.len() as u32,
        chunk: 1600,
    };
    let msg24_bytes = (od24.payload.len() * 4 + od24.indices.len() * 3 + 64) as f64;
    let r = bench("u24 delta index encode (sparse)", 2, 20, || od24.encode());
    run(r, msg24_bytes);

    let buf24 = od24.encode();
    let r = bench("u24 delta index decode (view iter)", 2, 20, || {
        let v = OpDataView::parse(&buf24).unwrap();
        v.indices_iter().map(|i| i as u64).sum::<u64>()
    });
    run(r, msg24_bytes);

    // Socket frame codec (tcp transport): checksum + header around a
    // 64 KiB Packet body, encoded and incrementally re-decoded. This is
    // the per-message overhead the transport adds on top of the OP-Data
    // payload codec; bench-diff gates it like every other hot-path op.
    let frame_body = vec![0x5Au8; 64 * 1024];
    let mut frame_buf = Vec::new();
    let frame_pool = PacketPool::new();
    let mut framer = Framer::with_pool(frame_pool.clone());
    let r = bench("frame encode/decode (64KiB packet)", 4, 50, || {
        encode_frame(Lane::Fwd, FrameKind::Packet, &frame_body, &mut frame_buf);
        framer.push(&frame_buf);
        let f = framer.next().unwrap().unwrap();
        let n = f.body.len();
        frame_pool.give(f.body);
        n
    });
    run(r, frame_body.len() as f64);

    // SIMD wire kernels (util::simd / util::fnv): the scalar reference vs
    // the runtime-dispatched form for each per-message hot loop, as row
    // pairs so bench-diff tracks the vector speedup — and a regression in
    // either path — kernel by kernel.
    println!("\nsimd dispatch level: {}\n", simd::level().name());

    let r = bench("fnv1a64 64KiB (scalar)", 4, 50, || fnv::fnv1a64_scalar(&frame_body));
    run(r, frame_body.len() as f64);
    let r = bench("fnv1a64 64KiB (dispatched)", 4, 50, || fnv::fnv1a64(&frame_body));
    run(r, frame_body.len() as f64);

    let r = bench("absmax 19.66MB (scalar)", 2, 10, || simd::max_abs_scalar(&act));
    run(r, act_bytes);
    let r = bench("absmax 19.66MB (dispatched)", 2, 10, || simd::max_abs(&act));
    run(r, act_bytes);

    let mut bits = vec![0u32; act.len()];
    let r = bench("abs-bits 19.66MB (scalar)", 2, 10, || {
        simd::abs_bits_scalar(&act, &mut bits);
        bits[0]
    });
    run(r, act_bytes);
    let r = bench("abs-bits 19.66MB (dispatched)", 2, 10, || {
        simd::abs_bits(&act, &mut bits);
        bits[0]
    });
    run(r, act_bytes);

    let scale = simd::max_abs(&act) / 127.0;
    let mut codes = Vec::new();
    let r = bench("int8 quantize codes (scalar)", 2, 10, || {
        codes.clear();
        simd::quantize_codes_scalar(&act, scale, &mut codes);
        codes.len()
    });
    run(r, act_bytes);
    let r = bench("int8 quantize codes (dispatched)", 2, 10, || {
        codes.clear();
        simd::quantize_codes(&act, scale, &mut codes);
        codes.len()
    });
    run(r, act_bytes);

    let r = bench("int8 dequant codes (scalar)", 2, 10, || {
        simd::dequant_into_scalar(&codes, scale, &mut dense);
        dense[0]
    });
    run(r, act_bytes);
    let r = bench("int8 dequant codes (dispatched)", 2, 10, || {
        simd::dequant_into(&codes, scale, &mut dense);
        dense[0]
    });
    run(r, act_bytes);

    // Sparse gather/scatter over the Top-K support computed above
    // (~196k kept values at ratio 100).
    let sparse_bytes = c.indices.len() as f64 * 4.0;
    let mut gath = Vec::new();
    let r = bench("sparse gather 196k (scalar)", 2, 20, || {
        gath.clear();
        simd::gather_f32_scalar(&act, &c.indices, &mut gath);
        gath.len()
    });
    run(r, sparse_bytes);
    let r = bench("sparse gather 196k (dispatched)", 2, 20, || {
        gath.clear();
        simd::gather_f32(&act, &c.indices, &mut gath);
        gath.len()
    });
    run(r, sparse_bytes);

    let r = bench("sparse scatter 196k (scalar)", 2, 20, || {
        simd::scatter_f32_mem_scalar(&c.indices, &c.values, &mut dense);
        dense[0]
    });
    run(r, sparse_bytes);
    let r = bench("sparse scatter 196k (dispatched)", 2, 20, || {
        simd::scatter_f32(&c.indices, &c.values, &mut dense);
        dense[0]
    });
    run(r, sparse_bytes);

    let mut lebuf = Vec::new();
    let r = bench("f32 LE encode 19.66MB (scalar)", 2, 10, || {
        lebuf.clear();
        simd::extend_f32_le_scalar(&mut lebuf, &act);
        lebuf.len()
    });
    run(r, act_bytes);
    let r = bench("f32 LE encode 19.66MB (dispatched)", 2, 10, || {
        lebuf.clear();
        simd::extend_f32_le(&mut lebuf, &act);
        lebuf.len()
    });
    run(r, act_bytes);

    let tb = testbed::testbed2(1);
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let r = bench("OP-Fence schedule (48 devices)", 1, 10, || {
        scheduler::opfence::OpFence::default().schedule(&dag, &tb).unwrap()
    });
    run(r, 0.0);

    let part = scheduler::by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
    let sp = StagePlan::from_partition(&dag, &part, &tb);
    let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), 8);
    let plan = CompressPlan::dense(tb.nodes.len());
    let r = bench("simnet iteration (48 stages, nb=8)", 2, 50, || {
        simulate_iteration(&sp, &tb, &sched, &plan).iter_s
    });
    run(r, 0.0);

    // Schedule-interpreter dispatch overhead: a middle stage executing
    // its full GPipe row (8 fwd + 8 bwd + update) over preloaded
    // channels with the NullBackend and a tiny payload, so the per-task
    // protocol cost (recv, decode, dispatch, encode, send, profile)
    // dominates — the steady-state loop the worker refactor must not slow.
    let disp_sched = PipelineSchedule::new(ScheduleKind::GPipe, 3, 8);
    let r = bench("interpreter dispatch (17 tasks, n=16)", 10, 200, || {
        interpreter_dispatch_once(&disp_sched, false)
    });
    run(r, 0.0);

    // Same row with the overlapped wire pipeline ON: adds two sender
    // threads + two prefetch threads per run, every packet crossing the
    // bounded handoff queues. The delta vs the row above is the overlap
    // machinery's fixed cost (spawn + queue + flush) at zero payload.
    let r = bench("overlap queue handoff (17 tasks, n=16)", 10, 200, || {
        interpreter_dispatch_once(&disp_sched, true)
    });
    run(r, 0.0);

    write_json(&results);
    println!("\n(recorded in EXPERIMENTS.md §Perf; machine-readable copy at BENCH_micro_hotpath.json)");
}

/// One full schedule-row execution of a middle (body) stage on the
/// production interpreter: channels preloaded with encoded packets in
/// schedule order, sends drained into held receivers.
fn interpreter_dispatch_once(sched: &PipelineSchedule, overlap: bool) -> u32 {
    let n = 16usize;
    let n_micro = sched.n_micro;
    let plan = CompressPlan::dense(3);
    let (fwd_in_tx, fwd_in_rx) = channel::<Wire>();
    let (bwd_in_tx, bwd_in_rx) = channel::<Wire>();
    let (fwd_out_tx, fwd_out_rx) = channel::<Wire>();
    let (bwd_out_tx, bwd_out_rx) = channel::<Wire>();
    let (tx_driver, rx_driver) = channel::<Wire>();
    let mut enc = LinkEncoder::new(CompressKind::None, 1.0, n);
    let dense = vec![0.5f32; n];
    for m in 0..n_micro as u32 {
        let (buf, _) = enc.encode(0, 1, OpDataKind::Activation, 0, m, &dense);
        fwd_in_tx.send(Wire::Packet(buf)).unwrap();
    }
    for m in (0..n_micro as u32).rev() {
        let (buf, _) = enc.encode(2, 1, OpDataKind::Gradient, 0, m, &dense);
        bwd_in_tx.send(Wire::Packet(buf)).unwrap();
    }
    let mut links = StageLinks {
        stage: 1,
        device: 1,
        codec: StageCodec::from_plan(&plan, Some(2), Some(0), n),
        rx_fwd: chan::endpoint(fwd_in_rx),
        rx_bwd: Some(chan::endpoint(bwd_in_rx)),
        tx_fwd: Some(chan::link(fwd_out_tx)),
        tx_bwd: Some(chan::link(bwd_out_tx)),
        rx_labels: None,
        tx_driver: chan::link(tx_driver),
        // Drained packet buffers cycle back to the preloading encoder.
        fwd_return: Some(enc.pool()),
        bwd_return: Some(enc.pool()),
    };
    let mut backend = NullBackend::new(n, n_micro, false);
    let opts = RunOpts { overlap, ..RunOpts::default() };
    run_schedule_with(&mut links, &mut backend, &sched.tasks[1], 0, 1, opts).unwrap();
    // Receivers must outlive the run (sends would error otherwise).
    drop((fwd_out_rx, bwd_out_rx, rx_driver));
    backend.updates
}

/// Emit op -> {median_s, min_s, gb_per_s} to the repo root.
fn write_json(results: &[(BenchResult, f64)]) {
    let mut ops: Vec<(&str, Json)> = Vec::new();
    for (r, bytes) in results {
        let mut fields = vec![
            ("median_s", n(r.median_s)),
            ("min_s", n(r.min_s)),
            ("iters", n(r.iters as f64)),
        ];
        if *bytes > 0.0 {
            fields.push(("gb_per_s", n(bytes / r.median_s / 1e9)));
        }
        ops.push((r.name.as_str(), obj(fields)));
    }
    ops.push(("_threads", n(compress_threads() as f64)));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_micro_hotpath.json");
    match std::fs::write(&path, obj(ops).dump_pretty() + "\n") {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nWARN could not write {}: {e}", path.display()),
    }
}
