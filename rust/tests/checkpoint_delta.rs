//! Incremental (base + delta) checkpoint integration tests: real broker
//! runs over the Null compute backend, gating the end-to-end pipeline —
//! worker shadow diffing, `Wire::SnapshotDelta`, broker materialization,
//! on-disk chain layout, rebase policy, corrupt-layer fallback, and
//! kill-and-restore determinism on top of a delta chain.

use fusionllm::broker::{self, Job};
use fusionllm::checkpoint;
use fusionllm::scheduler::replan::ReplanMode;
use fusionllm::util::json::Json;
use fusionllm::worker::BackendKind;
use std::path::{Path, PathBuf};

fn ckpt_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "fusionllm-ckptdelta-test-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A fast artifact-free job: 4 Null stages pinned to devices 0..4.
fn null_job(tag: &str) -> Job {
    Job {
        config: "ckpt-delta-test".into(),
        backend: BackendKind::Null,
        iters: 8,
        n_micro: 2,
        placement: Some(vec![0, 1, 2, 3]),
        straggler_threshold: 1e9,
        heartbeat_s: 0.02,
        heartbeat_timeout: 50,
        checkpoint_every: 2,
        checkpoint_dir: ckpt_dir(tag),
        ..Job::default()
    }
}

/// The layer kind a version's manifest declares ("base" or "delta").
fn layer_kind(dir: &Path, iter: u32) -> String {
    let m = Json::parse_file(&dir.join(format!("ckpt-{iter:08}/manifest.json")))
        .expect("manifest readable");
    m.get("kind").as_str().expect("kind field").to_string()
}

#[test]
fn delta_chain_restores_bitwise_equal_to_full_snapshots() {
    // Two identical healthy runs; one persists every version as a full
    // base (`checkpoint_rebase_every: 1`), the other uses the default
    // delta chains. Replaying the chain must reconstruct the exact same
    // bit patterns a full snapshot would have stored.
    let full = null_job("fullref");
    let delta = null_job("deltaref");
    let full_report = broker::run(&Job {
        checkpoint_rebase_every: 1,
        ..full.clone()
    })
    .unwrap();
    let delta_report = broker::run(&delta).unwrap();

    // The full-snapshot run accumulated no delta bytes; the delta run did,
    // and well under the counterfactual full cost (the >=4x acceptance bar).
    assert_eq!(full_report.checkpoint_bytes_delta, 0.0);
    assert!(delta_report.checkpoint_bytes_delta > 0.0);
    assert!(
        delta_report.checkpoint_bytes_full >= 4.0 * delta_report.checkpoint_bytes_delta,
        "delta layers not small enough: {} full vs {} delta",
        delta_report.checkpoint_bytes_full,
        delta_report.checkpoint_bytes_delta
    );
    assert_eq!(layer_kind(&delta.checkpoint_dir, 2), "base");
    assert_eq!(layer_kind(&delta.checkpoint_dir, 4), "delta");
    assert_eq!(layer_kind(&delta.checkpoint_dir, 6), "delta");
    assert_eq!(layer_kind(&full.checkpoint_dir, 6), "base");

    let a = checkpoint::load_latest(&full.checkpoint_dir).unwrap().unwrap();
    let b = checkpoint::load_latest(&delta.checkpoint_dir).unwrap().unwrap();
    assert_eq!(a.iter, 6);
    assert_eq!(b.iter, 6);
    assert_eq!(a.corpus_batches, b.corpus_batches);
    assert_eq!(a.states.len(), b.states.len());
    for (s, (x, y)) in a.states.iter().zip(&b.states).enumerate() {
        assert_eq!(x, y, "stage {s}: delta-chain restore differs from full");
    }
    let _ = std::fs::remove_dir_all(&full.checkpoint_dir);
    let _ = std::fs::remove_dir_all(&delta.checkpoint_dir);
}

#[test]
fn corrupt_middle_delta_falls_back_to_valid_chain_prefix() {
    // base 2 <- delta 4 <- delta 6, written by a real run. Flipping a byte
    // in the *middle* link invalidates both versions whose chains cross it
    // (4 and 6); restore must land on the base at 2, not fail.
    let base = null_job("middelta");
    broker::run(&base).unwrap();
    assert_eq!(checkpoint::versions(&base.checkpoint_dir), vec![2, 4, 6]);
    assert_eq!(layer_kind(&base.checkpoint_dir, 4), "delta");

    let victim = base.checkpoint_dir.join("ckpt-00000004/stage-1.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();

    let ck = checkpoint::load_latest(&base.checkpoint_dir)
        .unwrap()
        .expect("base survives");
    assert_eq!(ck.iter, 2, "chain crossing the corrupt link must be skipped");
    assert_eq!(ck.config, "ckpt-delta-test");
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
}

#[test]
fn rebase_every_bounds_the_chain_length() {
    // checkpoint-every 1 over 8 iterations writes versions 1..=7;
    // --checkpoint-rebase-every 3 must force a fresh base every third
    // version: base 1, deltas 2-3, base 4, deltas 5-6, base 7.
    let base = null_job("rebase");
    let report = broker::run(&Job {
        checkpoint_every: 1,
        checkpoint_rebase_every: 3,
        ..base.clone()
    })
    .unwrap();
    assert_eq!(report.losses.len(), 8);
    assert_eq!(
        checkpoint::versions(&base.checkpoint_dir),
        vec![1, 2, 3, 4, 5, 6, 7]
    );
    let kinds: Vec<String> = (1..=7)
        .map(|it| layer_kind(&base.checkpoint_dir, it))
        .collect();
    assert_eq!(
        kinds,
        vec!["base", "delta", "delta", "base", "delta", "delta", "base"],
        "rebase cadence drifted"
    );
    // Every version on disk is loadable despite the mixed layout.
    for it in 1..=7u32 {
        let ck = checkpoint::load_latest_at_or_before(&base.checkpoint_dir, it)
            .unwrap()
            .unwrap();
        assert_eq!(ck.iter, it);
    }
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
}

#[test]
fn kill_restores_from_a_delta_chain_with_bitwise_losses() {
    // Device 2 dies at iteration 5: the newest boundary is ckpt-4, a
    // *delta* layer, so recovery replays base 2 + delta 4 before
    // respawning the pipeline. The recovered trajectory must stay
    // bitwise-identical to an uninterrupted run.
    let base = null_job("killdelta");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        kill_device: Some(2),
        kill_at_iter: 5,
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    assert_eq!(churn.losses.len(), 8);
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!(r.resume_iter, 4, "newest boundary before the death");
    assert_eq!(
        layer_kind(&base.checkpoint_dir, 4),
        "delta",
        "the restored version must actually be a delta layer"
    );
    for (i, (a, b)) in clean.losses.iter().zip(&churn.losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "iter {i}: clean {a} != recovered {b}"
        );
    }
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
}
