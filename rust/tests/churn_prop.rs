//! Stateful property test for the elastic-membership machinery: random
//! short churn traces (≤ 3 events) run against the real Null-backend
//! broker, checked against a trivial membership model. No external
//! property-testing crate — a seeded `util::rng::Rng` generates the
//! traces, so every trial is reproducible from its printed seed.
//!
//! Model (what must hold for ANY legal trace whose survivors can host
//! the pipeline):
//!   - all requested iterations complete;
//!   - one recovery per scripted kill of a stage-hosting device;
//!   - one membership event per scripted join/rejoin, same device and
//!     kind, in script order;
//!   - the loss trajectory is bitwise-identical to an uninterrupted run.

use fusionllm::broker::{self, ChurnAction, ChurnEvent, ChurnTrace, Job};
use fusionllm::scheduler::replan::ReplanMode;
use fusionllm::util::rng::Rng;
use fusionllm::worker::BackendKind;

const ITERS: usize = 8;

fn null_job(tag: &str) -> Job {
    Job {
        config: "churn-prop".into(),
        backend: BackendKind::Null,
        iters: ITERS,
        n_micro: 2,
        placement: Some(vec![0, 1, 2, 3]),
        straggler_threshold: 1e9,
        heartbeat_s: 0.02,
        heartbeat_timeout: 50,
        checkpoint_every: 2,
        checkpoint_dir: std::env::temp_dir()
            .join(format!("fusionllm-churn-prop-{tag}-{}", std::process::id())),
        ..Job::default()
    }
}

/// Generate a random legal trace: 1–3 strictly-increasing events, at
/// most one kill (of an initially-placed device — guaranteed to host a
/// stage, so the model's recovery count is exact), joins of never-seen
/// devices 8+, and a rejoin only of the killed device. Constraining the
/// generator this tightly keeps the model trivial; richer interleavings
/// (concurrent kills, kill-after-rejoin) are pinned in `churn.rs`.
fn random_trace(rng: &mut Rng) -> ChurnTrace {
    let n_events = 1 + rng.below(3) as usize;
    let mut at_iter = 1 + rng.below(2) as u32;
    let mut killed: Option<usize> = None;
    let mut had_kill = false;
    let mut next_join_dev = 8 + rng.below(8) as usize;
    let mut events = Vec::new();
    for _ in 0..n_events {
        if at_iter as usize >= ITERS - 1 {
            break;
        }
        let roll = rng.below(3);
        let (action, device) = if roll == 0 && !had_kill {
            let d = rng.below(4) as usize;
            killed = Some(d);
            had_kill = true;
            (ChurnAction::Kill, d)
        } else if roll == 1 && killed.is_some() {
            (ChurnAction::Rejoin, killed.take().unwrap())
        } else {
            let d = next_join_dev;
            next_join_dev += 1;
            (ChurnAction::Join, d)
        };
        events.push(ChurnEvent { action, device, at_iter });
        // Strictly increasing iterations: a rejoin always lands strictly
        // after its kill, as `validate` requires.
        at_iter += 1 + rng.below(2) as u32;
    }
    ChurnTrace { events }
}

#[test]
fn random_short_traces_match_the_membership_model() {
    let base = null_job("ref");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();

    for trial in 0..5u64 {
        let seed = 0xC0FFEE ^ trial;
        let mut rng = Rng::new(seed);
        let trace = random_trace(&mut rng);
        // Generator sanity: every emitted trace must be legal.
        trace
            .validate(&[0, 1, 2, 3])
            .unwrap_or_else(|e| panic!("seed {seed}: generator emitted {trace:?}: {e:#}"));

        let n_kills = trace.kills().count();
        let expect: Vec<(usize, &str)> = trace
            .admissions()
            .map(|e| (e.device, e.action.name()))
            .collect();

        let job = null_job(&format!("t{trial}"));
        let _ = std::fs::remove_dir_all(&job.checkpoint_dir);
        let churn = broker::run(&Job {
            churn: Some(trace.clone()),
            replan: ReplanMode::Auto,
            ..job.clone()
        })
        .unwrap_or_else(|e| panic!("seed {seed}: trace {trace:?} failed: {e:#}"));
        let _ = std::fs::remove_dir_all(&job.checkpoint_dir);

        assert_eq!(
            churn.losses.len(),
            ITERS,
            "seed {seed}: trace {trace:?} did not finish"
        );
        assert_eq!(
            churn.recoveries.len(),
            n_kills,
            "seed {seed}: trace {trace:?} recoveries {:?}",
            churn.recoveries
        );
        let got: Vec<(usize, &str)> = churn
            .joins
            .iter()
            .map(|j| (j.device, j.kind.as_str()))
            .collect();
        assert_eq!(got, expect, "seed {seed}: trace {trace:?} joins {:?}", churn.joins);
        for (i, (a, b)) in clean.losses.iter().zip(&churn.losses).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "seed {seed}: trace {trace:?} diverged at iter {i}: {a} != {b}"
            );
        }
    }
}
