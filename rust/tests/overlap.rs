//! Overlapped wire pipeline differentials.
//!
//! The interpreter's overlap mode (on by default) moves per-link
//! compression + `OpData` encode + transport send onto dedicated sender
//! threads and decodes inbound packets on prefetch threads. Because each
//! link's codec state (error-feedback residual, packet pool) still lives
//! on exactly one thread and jobs flow through a strict-FIFO bounded
//! queue, the byte stream — and therefore the loss trajectory — must be
//! bitwise identical to `--overlap off` on every transport:
//!
//!   * chan (in-process), loopback TCP relay, and TCP mesh;
//!   * with Top-K + int8 and the u24 delta index codec in the loop
//!     (error feedback exercises the residual-moves-with-the-encoder
//!     invariant);
//!   * across a kill-mid-run checkpoint-restore recovery.
//!
//! A paced run (`--link-delay`) then checks the performance claim: with
//! per-send wire delay injected, overlap-on must beat overlap-off by a
//! clear margin, and the measured times must sit within tolerance of the
//! `simnet` predictions for the same (synthetic) testbed.

use fusionllm::broker::{self, Job};
use fusionllm::cluster::{CompNode, GpuModel, NetGraph, Testbed};
use fusionllm::compress::{CompressKind, CompressPlan, ValueCodec};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler::replan::ReplanMode;
use fusionllm::simnet::{simulate_iteration_with, SimOpts, StagePlan};
use fusionllm::transport::{DataPlane, TransportKind};
use fusionllm::worker::{run_worker, BackendKind, WorkerOpts};
use std::net::TcpListener;
use std::path::PathBuf;
use std::time::{Duration, Instant};

// ---- helpers -----------------------------------------------------------

fn ckpt_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "fusionllm-overlap-test-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A fast artifact-free job: 4 Null stages pinned to devices 0..4, with
/// Top-K + int8-u24 on the wire so the overlap threads carry real codec
/// state (error-feedback residuals, delta-packed indices).
fn null_job(tag: &str) -> Job {
    Job {
        config: "overlap-test".into(),
        backend: BackendKind::Null,
        iters: 6,
        n_micro: 2,
        placement: Some(vec![0, 1, 2, 3]),
        compress: CompressKind::TopK,
        ratio: 4.0,
        value_codec: ValueCodec::Int8Delta,
        straggler_threshold: 1e9,
        heartbeat_s: 0.02,
        heartbeat_timeout: 50,
        token: "overlap-test-token".into(),
        checkpoint_dir: ckpt_dir(tag),
        ..Job::default()
    }
}

/// Run `job` over loopback TCP (one worker session per device on its own
/// thread), with the given data plane. Same harness as tests/transport.rs.
fn run_remote(
    job: &Job,
    devices: &[usize],
    data_plane: DataPlane,
) -> anyhow::Result<fusionllm::trainer::TrainReport> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let mut workers = Vec::new();
    for &d in devices {
        let opts = WorkerOpts {
            connect: addr.clone(),
            token: job.token.clone(),
            device: Some(d),
            artifacts: PathBuf::from("<unused-null-backend>"),
            retry: Duration::from_secs(10),
            peer_listen: (data_plane == DataPlane::Mesh).then(|| "127.0.0.1:0".into()),
        };
        workers.push(
            std::thread::Builder::new()
                .name(format!("overlap-worker-{d}"))
                .spawn(move || run_worker(&opts))
                .unwrap(),
        );
    }
    let job = Job {
        transport: TransportKind::Tcp,
        data_plane,
        workers: Some(devices.len()),
        ..job.clone()
    };
    let report = broker::run_with_listener(&job, Some(listener));
    for w in workers {
        w.join()
            .expect("worker thread panicked")
            .expect("worker session failed");
    }
    report
}

fn assert_bitwise_equal_losses(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: loss trajectory lengths differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: iter {i}: {x} != {y} — overlap changed the math"
        );
    }
}

// ---- bitwise differentials: overlap on == overlap off ------------------

#[test]
fn overlap_on_matches_off_bitwise_chan() {
    let base = null_job("chan");
    let on = broker::run(&Job { overlap: true, ..base.clone() }).unwrap();
    let off = broker::run(&Job { overlap: false, ..base.clone() }).unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(on.losses.len(), 6);
    assert_bitwise_equal_losses(&on.losses, &off.losses, "chan");
    // Accounting flows through the sender threads' flush on the overlap
    // path; the wire counts are integers so the sums must be exact.
    assert_eq!(
        on.wire_bytes.iter().sum::<f64>(),
        off.wire_bytes.iter().sum::<f64>(),
        "overlap changed the wire-byte accounting"
    );
}

#[test]
fn overlap_on_matches_off_bitwise_tcp() {
    let base = null_job("tcp");
    let on = run_remote(&Job { overlap: true, ..base.clone() }, &[0, 1, 2, 3], DataPlane::Relay)
        .unwrap();
    let off =
        run_remote(&Job { overlap: false, ..base.clone() }, &[0, 1, 2, 3], DataPlane::Relay)
            .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_bitwise_equal_losses(&on.losses, &off.losses, "tcp");
    assert!(on.recoveries.is_empty() && off.recoveries.is_empty());
    assert_eq!(
        on.wire_bytes.iter().sum::<f64>(),
        off.wire_bytes.iter().sum::<f64>(),
    );
}

#[test]
fn overlap_on_matches_off_bitwise_mesh() {
    // Direct worker↔worker peer links, with a non-default credit window
    // so the batched credit-return path is exercised (window 4 => one
    // Credit frame per drain batch, partial batches flushed before
    // blocking reads).
    let base = Job { mesh_window: 4, ..null_job("mesh") };
    let on = run_remote(&Job { overlap: true, ..base.clone() }, &[0, 1, 2, 3], DataPlane::Mesh)
        .unwrap();
    let off =
        run_remote(&Job { overlap: false, ..base.clone() }, &[0, 1, 2, 3], DataPlane::Mesh)
            .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_bitwise_equal_losses(&on.losses, &off.losses, "mesh");
    assert_eq!(on.relayed_packet_bytes, 0.0, "mesh run relayed packets via the broker");
    assert_eq!(off.relayed_packet_bytes, 0.0);
    assert!(on.peer_packet_bytes > 0.0, "mesh run reported no peer-direct traffic");
    assert_eq!(on.peer_packet_bytes, off.peer_packet_bytes);
}

// ---- kill-mid-run recovery with overlap enabled ------------------------

#[test]
fn overlap_kill_recovery_matches_blocking_clean_run() {
    // Device 1's worker vanishes at iteration 3 with the overlap pipeline
    // ON: the sender threads hit the dead link, the stage quiesces, the
    // broker re-plans onto the spare (device 4), restores the iter-2
    // checkpoint, and the final trajectory still matches an uninterrupted
    // *blocking* chan run bitwise — recovery and overlap compose.
    let base = Job {
        checkpoint_every: 2,
        replan: ReplanMode::Auto,
        ..null_job("kill")
    };
    let clean = broker::run(&Job {
        overlap: false,
        checkpoint_every: 0,
        replan: ReplanMode::Off,
        ..base.clone()
    })
    .unwrap();
    let churn = run_remote(
        &Job {
            overlap: true,
            kill_device: Some(1),
            kill_at_iter: 3,
            ..base.clone()
        },
        &[0, 1, 2, 3, 4],
        DataPlane::Relay,
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 6, "all iterations must complete");
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!((r.stage, r.device, r.died_iter), (1, 1, 3));
    assert!(!r.to.contains(&1), "dead device still placed: {:?}", r.to);
    assert_bitwise_equal_losses(&clean.losses, &churn.losses, "kill-recovery");
}

// ---- paced wall-clock: overlap wins, simnet predicts it ----------------

/// Synthetic 4-node testbed whose every link has latency `alpha_s` and
/// effectively infinite bandwidth — the simnet mirror of `--link-delay`.
fn paced_testbed(n: usize, alpha_s: f64) -> Testbed {
    let mut net = NetGraph::new(n);
    for i in 0..n {
        for j in (i + 1)..n {
            net.set_link(i, j, alpha_s, 1e15);
        }
    }
    let nodes = (0..n)
        .map(|id| CompNode {
            id,
            name: format!("paced/{id}"),
            gpu: GpuModel::A100,
            lambda: 1.0,
            cluster: "A".into(),
            machine: id,
        })
        .collect();
    Testbed { name: "paced".into(), nodes, net }
}

#[test]
fn paced_overlap_beats_blocking_and_simnet_predicts_it() {
    // Forward compute (--pace) equals the injected per-send wire delay,
    // with enough microbatches that the steady-state slope dominates the
    // pipeline fill: blocking pays compute + send per micro, overlap pays
    // max(compute, send) — the send runs on the dedicated sender thread
    // while the next microbatch computes.
    const DELAY_S: f64 = 0.02;
    const ITERS: usize = 3;
    let base = Job {
        iters: ITERS,
        n_micro: 16,
        pace_s: DELAY_S,
        link_delay_s: DELAY_S,
        // Dense f32 wire: keeps the paced run aligned with the dense
        // simnet plan below (compression would change neither side's
        // *timing structure*, only the beta term, which is ~0 here).
        compress: CompressKind::None,
        ratio: 1.0,
        value_codec: ValueCodec::F32,
        ..null_job("paced")
    };

    let t0 = Instant::now();
    let on = broker::run(&Job { overlap: true, ..base.clone() }).unwrap();
    let wall_on = t0.elapsed().as_secs_f64() / ITERS as f64;
    let t1 = Instant::now();
    let off = broker::run(&Job { overlap: false, ..base.clone() }).unwrap();
    let wall_off = t1.elapsed().as_secs_f64() / ITERS as f64;
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    // Same math, pacing or not.
    assert_bitwise_equal_losses(&on.losses, &off.losses, "paced");

    let speedup = wall_off / wall_on;
    assert!(
        speedup >= 1.2,
        "overlap speedup {speedup:.2}x < 1.2x (on {wall_on:.3}s, off {wall_off:.3}s)"
    );

    // simnet mirror: 4 stages with DELAY_S of forward compute (--pace
    // paces forwards only; Null backwards are ~free), every link
    // alpha = DELAY_S. The model must predict the measured ordering and
    // be in the right ballpark on both absolute times (broker/setup
    // overhead and scheduling slack are real but small next to 20 ms
    // per hop × 16 microbatches).
    let plan = StagePlan {
        devices: vec![0, 1, 2, 3],
        fwd_s: vec![DELAY_S; 4],
        bwd_s: vec![1e-6; 4],
        update_s: vec![1e-6; 4],
        act_bytes: vec![1.0; 3],
    };
    let tb = paced_testbed(4, DELAY_S);
    let sched = PipelineSchedule::new(ScheduleKind::GPipe, 4, base.n_micro);
    let dense = CompressPlan::dense(4);
    let pred_on =
        simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::overlapped()).iter_s;
    let pred_off =
        simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::blocking()).iter_s;
    assert!(pred_off > pred_on, "model: blocking {pred_off} !> overlapped {pred_on}");
    // Generous 2x tolerance either way: CI machines are noisy and the
    // measured run includes scheduling slack the model doesn't charge.
    for (what, meas, pred) in
        [("overlap on", wall_on, pred_on), ("overlap off", wall_off, pred_off)]
    {
        assert!(
            meas >= pred * 0.5 && meas <= pred * 2.0,
            "{what}: measured {meas:.3}s vs predicted {pred:.3}s — outside 2x tolerance"
        );
    }
}
