//! Property-based invariants over the coordinator (proptest-lite: seeded
//! xoshiro generators + many trials, since proptest is unavailable
//! offline). Every test names the invariant it defends.

use fusionllm::cluster::louvain::{louvain, modularity};
use fusionllm::cluster::NetGraph;
use fusionllm::compress::{Compressor, Int8Quantizer, NoCompress, RandomK, TopK};
use fusionllm::opdag::data::{CompressCfg, OpData, OpDataKind};
use fusionllm::opdag::{Dag, OpKind, Partition};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::util::json::{arr, n, obj, s, Json};
use fusionllm::util::math::kth_largest_abs;
use fusionllm::util::rng::Rng;

// ---------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------

/// Random layered DAG: a chain with occasional side-branches that re-merge
/// (degree <= 2, like real DNNs — Observation 1).
fn random_dag(rng: &mut Rng) -> Dag {
    let mut d = Dag::default();
    let input = d.add("input", OpKind::Placeholder, &[], 0.0, 64.0, 0.0);
    let mut prev =
        d.add("stem", OpKind::Parametric, &[input], 1e6 * (1.0 + rng.f64()), 1e3, 1e3);
    let n_ops = 3 + rng.below(20) as usize;
    let mut branch: Option<usize> = None;
    for i in 0..n_ops {
        if branch.is_none() && rng.f64() < 0.2 {
            // Open a side branch from a fresh variable.
            let v = d.add(&format!("var{i}"), OpKind::Variable, &[], 0.0, 1e3, 1e3);
            let r = d.add(
                &format!("branch{i}"),
                OpKind::NonParametric,
                &[v],
                1e5,
                1e3,
                0.0,
            );
            branch = Some(r);
        } else if let Some(b) = branch.take() {
            prev = d.add(
                &format!("merge{i}"),
                OpKind::NonParametric,
                &[prev, b],
                1e5,
                1e3,
                0.0,
            );
        } else {
            prev = d.add(
                &format!("op{i}"),
                OpKind::Parametric,
                &[prev],
                1e6 * (1.0 + rng.f64()),
                1e3 * (1.0 + rng.f64()),
                1e3,
            );
        }
    }
    let label = d.add("label", OpKind::Placeholder, &[], 0.0, 64.0, 0.0);
    d.add("loss", OpKind::Loss, &[prev, label], 1e4, 4.0, 0.0);
    d
}

/// Random contiguous partition of the dag over up to `max_dev` devices.
fn random_partition(rng: &mut Rng, dag: &Dag, max_dev: usize) -> Partition {
    let chain = dag.compute_chain();
    let k = 1 + rng.below(max_dev.min(chain.len()) as u64) as usize;
    let mut assign = vec![usize::MAX; dag.len()];
    // k-1 sorted random cut points.
    let mut cuts: Vec<usize> = (0..k - 1).map(|_| 1 + rng.below(chain.len() as u64 - 1) as usize).collect();
    cuts.sort_unstable();
    let mut dev = 0;
    for (i, &op) in chain.iter().enumerate() {
        while dev < cuts.len() && i >= cuts[dev] {
            dev += 1;
        }
        assign[op] = dev;
    }
    for op in &dag.ops {
        if op.kind == OpKind::Placeholder {
            assign[op.id] = assign[op.users[0]];
        }
    }
    Partition::new(assign)
}

// ---------------------------------------------------------------------
// OP-DAG / partition invariants (the routing core)
// ---------------------------------------------------------------------

#[test]
fn prop_subdag_message_sets_are_symmetric() {
    // INVARIANT (Table 3): every (src,dst) in some sub-DAG's send_acti
    // appears in exactly one other sub-DAG's required_acti, and gradients
    // mirror activations for grad-requiring producers.
    let mut rng = Rng::new(0xDA6);
    for trial in 0..200 {
        let dag = random_dag(&mut rng);
        dag.validate().unwrap();
        let part = random_partition(&mut rng, &dag, 6);
        part.validate(&dag).unwrap();
        let subs = part.sub_dags(&dag);

        // Every op appears exactly once.
        let mut seen = vec![0usize; dag.len()];
        for sd in &subs {
            for &op in &sd.ops {
                seen[op] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "trial {trial}: op coverage {seen:?}");

        let all_send: Vec<_> = subs.iter().flat_map(|s| s.send_acti.clone()).collect();
        let all_req: Vec<_> = subs.iter().flat_map(|s| s.required_acti.clone()).collect();
        let mut a = all_send.clone();
        let mut b = all_req.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b, "trial {trial}: acti send/require mismatch");

        let mut sg: Vec<_> = subs.iter().flat_map(|s| s.send_grad.clone()).collect();
        let mut rg: Vec<_> = subs.iter().flat_map(|s| s.required_grad.clone()).collect();
        sg.sort_unstable();
        rg.sort_unstable();
        assert_eq!(sg, rg, "trial {trial}: grad send/require mismatch");

        // Gradient edges exist iff the producer requires grad.
        for &(src, dst) in &all_send {
            let has_grad = sg.contains(&(dst, src));
            assert_eq!(
                has_grad,
                dag.ops[src].requires_grad(),
                "trial {trial}: grad mirror for ({src},{dst})"
            );
        }
    }
}

#[test]
fn prop_cut_edges_counts_cross_device_edges() {
    let mut rng = Rng::new(0xC075);
    for _ in 0..100 {
        let dag = random_dag(&mut rng);
        let part = random_partition(&mut rng, &dag, 5);
        let subs = part.sub_dags(&dag);
        let total_send: usize = subs.iter().map(|s| s.send_acti.len()).sum();
        assert_eq!(part.cut_edges(&dag), total_send);
    }
}

// ---------------------------------------------------------------------
// Compression invariants
// ---------------------------------------------------------------------

#[test]
fn prop_topk_keeps_largest_and_roundtrips() {
    let mut rng = Rng::new(0x70BA);
    for trial in 0..300 {
        let n = 1 + rng.below(3000) as usize;
        let ratio = 1.0 + rng.f64() * 200.0;
        let data: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 8.0).collect();
        let comp = TopK { ratio };
        let c = comp.compress(&data);
        let k = comp.k_for(n);
        assert_eq!(c.values.len(), k, "trial {trial}");
        assert_eq!(c.indices.len(), k);
        // indices strictly increasing & in range (decode safety).
        assert!(c.indices.windows(2).all(|w| w[0] < w[1]));
        assert!(c.indices.iter().all(|&i| (i as usize) < n));
        // kept magnitudes >= k-th largest.
        let tau = kth_largest_abs(&data, k);
        assert!(c.values.iter().all(|v| v.abs() >= tau - 1e-7));
        // roundtrip exactness on the support.
        let mut out = vec![0.0f32; n];
        comp.decompress(&c, &mut out);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            assert_eq!(out[i as usize], data[i as usize]);
            assert_eq!(out[i as usize], v);
        }
    }
}

#[test]
fn prop_compression_error_ordering() {
    // INVARIANT: for the same ratio, TopK's L2 error <= RandomK's (in
    // expectation — we allow rare ties but never a large inversion).
    let mut rng = Rng::new(0xE44);
    let mut topk_wins = 0;
    let trials = 60;
    for t in 0..trials {
        let n = 500 + rng.below(2000) as usize;
        let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let ratio = 10.0 + rng.f64() * 40.0;
        let err = |out: &[f32]| -> f64 {
            data.iter().zip(out).map(|(a, b)| ((a - b) * (a - b)) as f64).sum()
        };
        let tk = TopK { ratio };
        let rk = RandomK { ratio, seed: t as u64 };
        let mut out_t = vec![0.0; n];
        let mut out_r = vec![0.0; n];
        tk.decompress(&tk.compress(&data), &mut out_t);
        rk.decompress(&rk.compress(&data), &mut out_r);
        if err(&out_t) <= err(&out_r) {
            topk_wins += 1;
        }
    }
    assert_eq!(topk_wins, trials, "TopK must always beat RandomK on L2");
}

#[test]
fn prop_int8_bounded_error_and_wire_size() {
    let mut rng = Rng::new(0x1E8);
    for _ in 0..100 {
        let n = 1 + rng.below(4000) as usize;
        let scale_mag = 10f32.powi(rng.range(-3, 3) as i32);
        let data: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * scale_mag).collect();
        let q = Int8Quantizer;
        let c = q.compress(&data);
        let mut out = vec![0.0f32; n];
        q.decompress(&c, &mut out);
        let absmax = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= absmax / 127.0 * 1.01 + 1e-9);
        }
        // 4x smaller than dense (+constant).
        let dense = NoCompress.compress(&data);
        assert!(c.wire_bytes() <= dense.wire_bytes() / 4.0 + 8.0);
    }
}

// ---------------------------------------------------------------------
// OP-Data wire format: fuzz for panics, roundtrip for fidelity
// ---------------------------------------------------------------------

#[test]
fn prop_opdata_roundtrip_random() {
    let mut rng = Rng::new(0x0DA7A);
    for _ in 0..300 {
        let np = rng.below(200) as usize;
        let ni = rng.below(200) as usize;
        let nb = rng.below(100) as usize;
        let mut od = OpData::dense(
            rng.below(1000) as usize,
            rng.below(1000) as usize,
            if rng.f64() < 0.5 { OpDataKind::Activation } else { OpDataKind::Gradient },
            rng.below(u32::MAX as u64) as u32,
            rng.below(64) as u32,
            (0..np).map(|_| rng.f32() - 0.5).collect(),
        );
        od.indices = (0..ni).map(|_| rng.below(1 << 20) as u32).collect();
        od.bytes_payload = (0..nb).map(|_| rng.below(256) as u8).collect();
        od.is_loss = rng.f64() < 0.5;
        od.require_grad = rng.f64() < 0.5;
        od.compress = match rng.below(4) {
            0 => CompressCfg::None,
            1 => CompressCfg::TopK { ratio: rng.f64() * 100.0, total_len: 1 << 20 },
            2 => CompressCfg::RandomK {
                ratio: rng.f64() * 100.0,
                total_len: 1 << 20,
                seed: rng.next_u64(),
            },
            _ => CompressCfg::Int8 { scale: rng.f32(), total_len: nb as u32 },
        };
        let enc = od.encode();
        let back = OpData::decode(&enc).unwrap();
        assert_eq!(back.src_op, od.src_op);
        assert_eq!(back.dst_op, od.dst_op);
        assert_eq!(back.kind, od.kind);
        assert_eq!(back.is_loss, od.is_loss);
        assert_eq!(back.require_grad, od.require_grad);
        assert_eq!(back.local_iter, od.local_iter);
        assert_eq!(back.micro_batch, od.micro_batch);
        assert_eq!(back.compress, od.compress);
        assert_eq!(back.payload, od.payload);
        assert_eq!(back.indices, od.indices);
        assert_eq!(back.bytes_payload, od.bytes_payload);
    }
}

#[test]
fn prop_opdata_decode_never_panics_on_corruption() {
    // FAILURE INJECTION: random truncations and byte flips must yield
    // Err or a decoded value — never a panic.
    let mut rng = Rng::new(0xFA11);
    let base = {
        let mut od = OpData::dense(1, 2, OpDataKind::Activation, 3, 4, vec![1.0; 64]);
        od.indices = (0..32).collect();
        od.compress = CompressCfg::TopK { ratio: 2.0, total_len: 64 };
        od.encode()
    };
    for _ in 0..500 {
        let mut buf = base.clone();
        match rng.below(3) {
            0 => {
                let cut = rng.below(buf.len() as u64) as usize;
                buf.truncate(cut);
            }
            1 => {
                for _ in 0..1 + rng.below(8) {
                    let i = rng.below(buf.len() as u64) as usize;
                    buf[i] ^= rng.below(256) as u8;
                }
            }
            _ => {
                let extra = rng.below(16) as usize;
                buf.extend(std::iter::repeat(0xAB).take(extra));
            }
        }
        let _ = OpData::decode(&buf); // must not panic
    }
}

// ---------------------------------------------------------------------
// Pipeline schedules & Louvain
// ---------------------------------------------------------------------

#[test]
fn prop_schedules_valid_for_all_shapes() {
    for s in 1..=8 {
        for m in 1..=8 {
            for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
                let sched = PipelineSchedule::new(kind, s, m);
                sched.validate().unwrap();
                // 1F1B never stashes more than GPipe.
                if kind == ScheduleKind::OneFOneB {
                    let g = PipelineSchedule::new(ScheduleKind::GPipe, s, m);
                    for st in 0..s {
                        assert!(sched.peak_stash(st) <= g.peak_stash(st));
                    }
                }
            }
        }
    }
}

#[test]
fn prop_louvain_planted_partition_recovers_islands() {
    let mut rng = Rng::new(0x10BA);
    for trial in 0..20 {
        let k = 2 + rng.below(3) as usize; // 2-4 islands
        let per = 3 + rng.below(4) as usize; // 3-6 nodes each
        let n = k * per;
        let mut g = NetGraph::new(n);
        for i in 0..n {
            for j in (i + 1)..n {
                let same = i / per == j / per;
                let bw = if same {
                    1e9 * rng.uniform(0.8, 1.2)
                } else {
                    1e7 * rng.uniform(0.5, 1.5)
                };
                g.set_link(i, j, 1e-4, bw);
            }
        }
        let comm = louvain(&g);
        for i in 0..n {
            for j in 0..n {
                if i / per == j / per {
                    assert_eq!(comm[i], comm[j], "trial {trial} split island");
                } else {
                    assert_ne!(comm[i], comm[j], "trial {trial} merged islands");
                }
            }
        }
        // Modularity at least that of the trivial partition.
        assert!(modularity(&g, &comm) >= modularity(&g, &vec![0; n]));
    }
}

// ---------------------------------------------------------------------
// JSON roundtrip fuzz
// ---------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Num((rng.f64() * 2000.0 - 1000.0).round() / 4.0),
        3 => {
            let len = rng.below(12) as usize;
            Json::Str(
                (0..len)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c < 0x20 {
                            ' '
                        } else {
                            c as char
                        }
                    })
                    .collect(),
            )
        }
        4 => arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let fields = rng.below(5);
            obj((0..fields)
                .map(|i| {
                    let key = format!("k{i}");
                    (Box::leak(key.into_boxed_str()) as &str, random_json(rng, depth - 1))
                })
                .collect())
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::new(0x1503);
    for _ in 0..300 {
        let v = random_json(&mut rng, 3);
        let compact = Json::parse(&v.dump()).unwrap();
        let pretty = Json::parse(&v.dump_pretty()).unwrap();
        assert_eq!(compact, v);
        assert_eq!(pretty, v);
    }
    // Keep the imports used in all cfg paths.
    let _ = (n(1.0), s("x"));
}
