//! Transport-layer integration tests.
//!
//!   * Differential e2e: the same tiny Null-backend job over the
//!     in-process `ChanTransport` and over a loopback `TcpTransport`
//!     (real sockets, real worker sessions, real handshake) must produce
//!     bitwise-identical loss trajectories.
//!   * Churn over TCP: a worker process vanishing mid-run (socket EOF —
//!     what a `kill -9` looks like from the broker) triggers exactly one
//!     checkpoint-restore recovery and still matches the chan run
//!     bitwise.
//!   * Frame-codec property tests: randomized frame streams survive
//!     arbitrary read chunking; corrupted streams (truncation, flipped
//!     bits, version skew) error cleanly and never panic.

use fusionllm::broker::{self, Job};
use fusionllm::checkpoint::fnv1a64;
use fusionllm::scheduler::replan::ReplanMode;
use fusionllm::transport::frame::{encode_frame, FrameKind, Framer, Lane, FRAME_VERSION};
use fusionllm::transport::{DataPlane, TransportKind};
use fusionllm::util::rng::Rng;
use fusionllm::worker::{run_worker, BackendKind, WorkerOpts};
use std::net::TcpListener;
use std::path::PathBuf;
use std::time::Duration;

// ---- helpers -----------------------------------------------------------

fn ckpt_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!(
        "fusionllm-transport-test-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A fast artifact-free job: 4 Null stages pinned to devices 0..4.
fn null_job(tag: &str) -> Job {
    Job {
        config: "transport-test".into(),
        backend: BackendKind::Null,
        iters: 6,
        n_micro: 2,
        placement: Some(vec![0, 1, 2, 3]),
        straggler_threshold: 1e9,
        // 1 s death deadline (same rationale as the churn tests: loaded
        // CI machines must not misdeclare a descheduled live worker).
        heartbeat_s: 0.02,
        heartbeat_timeout: 50,
        token: "transport-test-token".into(),
        checkpoint_dir: ckpt_dir(tag),
        ..Job::default()
    }
}

/// Run `job` over loopback TCP: bind port 0, run one worker session per
/// entry of `devices` on its own thread (the same code path the
/// `fusionllm worker` process runs), and drive the broker to completion.
/// `data_plane` selects broker-relayed packet lanes (relay) or direct
/// worker↔worker peer connections (mesh — every worker binds a loopback
/// peer listener on an ephemeral port).
fn run_remote(
    job: &Job,
    devices: &[usize],
    data_plane: DataPlane,
) -> anyhow::Result<fusionllm::trainer::TrainReport> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let mut workers = Vec::new();
    for &d in devices {
        let opts = WorkerOpts {
            connect: addr.clone(),
            token: job.token.clone(),
            device: Some(d),
            artifacts: PathBuf::from("<unused-null-backend>"),
            retry: Duration::from_secs(10),
            peer_listen: (data_plane == DataPlane::Mesh).then(|| "127.0.0.1:0".into()),
        };
        workers.push(
            std::thread::Builder::new()
                .name(format!("test-worker-{d}"))
                .spawn(move || run_worker(&opts))
                .unwrap(),
        );
    }
    let job = Job {
        transport: TransportKind::Tcp,
        data_plane,
        workers: Some(devices.len()),
        ..job.clone()
    };
    let report = broker::run_with_listener(&job, Some(listener));
    for w in workers {
        w.join()
            .expect("worker thread panicked")
            .expect("worker session failed");
    }
    report
}

fn run_tcp(job: &Job, devices: &[usize]) -> anyhow::Result<fusionllm::trainer::TrainReport> {
    run_remote(job, devices, DataPlane::Relay)
}

fn run_mesh(job: &Job, devices: &[usize]) -> anyhow::Result<fusionllm::trainer::TrainReport> {
    run_remote(job, devices, DataPlane::Mesh)
}

fn assert_bitwise_equal_losses(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "loss trajectory lengths differ");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "iter {i}: chan {x} != tcp {y} — the transports diverged"
        );
    }
}

// ---- differential e2e --------------------------------------------------

#[test]
fn tcp_loopback_matches_chan_bitwise() {
    // Same job, two transports: in-process channels vs loopback sockets
    // with 4 worker sessions. Every activation/gradient crosses the
    // frame codec + broker relay; the losses must not change by a bit.
    let base = null_job("clean");
    let chan = broker::run(&base).unwrap();
    let tcp = run_tcp(&base, &[0, 1, 2, 3]).unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(chan.losses.len(), 6);
    assert_bitwise_equal_losses(&chan.losses, &tcp.losses);
    assert!(tcp.recoveries.is_empty() && tcp.replans.is_empty());
    // The wire accounting flows back over the driver lane too.
    assert!(tcp.wire_bytes.iter().sum::<f64>() > 0.0);
}

#[test]
fn tcp_killed_worker_recovers_and_matches_chan() {
    // Device 1's worker process vanishes at the top of iteration 3 (its
    // session closes — the broker sees what a kill -9 produces: EOF on
    // the socket). With a spare worker on device 4, the broker must
    // fail the device, re-plan onto the survivors, restore the iter-2
    // checkpoint and finish all 6 iterations — exactly one recovery,
    // loss trajectory bitwise-equal to an uninterrupted chan run.
    let base = Job {
        checkpoint_every: 2,
        replan: ReplanMode::Auto,
        ..null_job("churn")
    };
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        replan: ReplanMode::Off,
        ..base.clone()
    })
    .unwrap();
    let churn = run_tcp(
        &Job {
            kill_device: Some(1),
            kill_at_iter: 3,
            ..base.clone()
        },
        &[0, 1, 2, 3, 4],
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 6, "all iterations must complete");
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!((r.stage, r.device, r.died_iter), (1, 1, 3));
    assert_eq!(r.resume_iter, 2, "newest checkpoint is the iter-2 boundary");
    assert!(
        r.cause.contains("EOF")
            || r.cause.contains("closed")
            || r.cause.contains("deadline")
            || r.cause.contains("socket"),
        "death must be declared by the socket plane, got: {}",
        r.cause
    );
    assert!(!r.to.contains(&1), "dead device still placed: {:?}", r.to);
    assert!(
        r.to.iter().all(|d| [0, 2, 3, 4].contains(d)),
        "recovery placed a stage on a device with no worker: {:?}",
        r.to
    );
    assert_bitwise_equal_losses(&clean.losses, &churn.losses);
}

// ---- mesh data plane ---------------------------------------------------

#[test]
fn mesh_loopback_matches_chan_bitwise() {
    // Same job again, but the packet lanes run on direct worker↔worker
    // peer connections while the broker keeps control only. The losses
    // must still match chan bit-for-bit, and the byte accounting must
    // show the broker relayed nothing while peer links carried the
    // activation/gradient traffic.
    let base = null_job("mesh-clean");
    let chan = broker::run(&base).unwrap();
    let mesh = run_mesh(&base, &[0, 1, 2, 3]).unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_bitwise_equal_losses(&chan.losses, &mesh.losses);
    assert!(mesh.recoveries.is_empty() && mesh.replans.is_empty());
    assert_eq!(
        mesh.relayed_packet_bytes, 0.0,
        "mesh run relayed packet bytes through the broker"
    );
    assert!(
        mesh.peer_packet_bytes > 0.0,
        "mesh run reported no peer-direct traffic"
    );
}

#[test]
fn mesh_killed_worker_recovers_and_matches_chan() {
    // Satellite: peer-link death must flow into the *existing* recovery
    // machinery. Device 1's worker vanishes at iteration 3 — its peer
    // sockets die along with its broker connection. The broker (the one
    // death authority) declares the stage dead exactly once, re-plans
    // onto the survivors + spare, re-issues the mesh route table with a
    // fresh generation id, and the run finishes bitwise-equal to chan.
    let base = Job {
        checkpoint_every: 2,
        replan: ReplanMode::Auto,
        ..null_job("mesh-churn")
    };
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        replan: ReplanMode::Off,
        ..base.clone()
    })
    .unwrap();
    let churn = run_mesh(
        &Job {
            kill_device: Some(1),
            kill_at_iter: 3,
            ..base.clone()
        },
        &[0, 1, 2, 3, 4],
    )
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 6, "all iterations must complete");
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!((r.stage, r.device, r.died_iter), (1, 1, 3));
    assert!(!r.to.contains(&1), "dead device still placed: {:?}", r.to);
    assert_eq!(
        churn.relayed_packet_bytes, 0.0,
        "recovery must re-issue mesh routes, not fall back to broker relay"
    );
    assert_bitwise_equal_losses(&clean.losses, &churn.losses);
}

#[test]
fn mesh_requires_tcp_transport() {
    let job = Job {
        data_plane: DataPlane::Mesh,
        ..null_job("mesh-chan")
    };
    let err = broker::run(&job).unwrap_err().to_string();
    assert!(err.contains("mesh"), "unexpected error: {err}");
}

#[test]
fn tcp_without_heartbeats_is_rejected() {
    // The socket plane IS the deadline monitor — running it without the
    // liveness plane configured must fail fast, not hang.
    let job = Job {
        transport: TransportKind::Tcp,
        heartbeat_s: 0.0,
        ..null_job("nohb")
    };
    let err = broker::run(&job).unwrap_err().to_string();
    assert!(err.contains("heartbeat"), "unexpected error: {err}");
}

// ---- frame codec properties --------------------------------------------

const LANES: [Lane; 5] = [Lane::Fwd, Lane::Bwd, Lane::Labels, Lane::Driver, Lane::Ctl];
const KINDS: [FrameKind; 6] = [
    FrameKind::Packet,
    FrameKind::Data,
    FrameKind::Heartbeat,
    FrameKind::Stats,
    FrameKind::Hello,
    FrameKind::Stop,
];

fn random_stream(rng: &mut Rng, n_frames: usize) -> (Vec<u8>, Vec<(Lane, FrameKind, Vec<u8>)>) {
    let mut stream = Vec::new();
    let mut want = Vec::new();
    let mut buf = Vec::new();
    for _ in 0..n_frames {
        let lane = LANES[rng.below(LANES.len() as u64) as usize];
        let kind = KINDS[rng.below(KINDS.len() as u64) as usize];
        let len = rng.below(300) as usize;
        let body: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        encode_frame(lane, kind, &body, &mut buf);
        stream.extend_from_slice(&buf);
        want.push((lane, kind, body));
    }
    (stream, want)
}

#[test]
fn frame_stream_survives_arbitrary_chunking() {
    let mut rng = Rng::new(0xF7A3);
    for round in 0..50 {
        let (stream, want) = random_stream(&mut rng, 1 + (round % 7));
        let mut fr = Framer::new();
        let mut got = Vec::new();
        let mut pos = 0usize;
        while pos < stream.len() {
            let step = 1 + rng.below(97) as usize;
            let end = (pos + step).min(stream.len());
            fr.push(&stream[pos..end]);
            pos = end;
            while let Some(f) = fr.next().expect("valid stream must decode") {
                got.push((f.lane, f.kind, f.body));
            }
        }
        assert_eq!(got, want, "round {round}");
    }
}

#[test]
fn corrupted_streams_error_cleanly_never_panic() {
    let mut rng = Rng::new(0xBAD5EED);
    for round in 0..200 {
        let (mut stream, _) = random_stream(&mut rng, 1 + (round % 3));
        // Flip one random byte (or truncate): decoding must either yield
        // complete frames, report "need more bytes", or error — a panic
        // or a bogus frame count explosion fails the test harness.
        if rng.below(4) == 0 {
            let cut = rng.below(stream.len() as u64) as usize;
            stream.truncate(cut);
        } else {
            let i = rng.below(stream.len() as u64) as usize;
            stream[i] ^= 1 << rng.below(8);
        }
        let mut fr = Framer::new();
        fr.push(&stream);
        loop {
            match fr.next() {
                Ok(Some(_)) => continue, // frames before the corruption
                Ok(None) => break,       // truncated tail
                Err(e) => {
                    let msg = e.to_string();
                    assert!(!msg.is_empty());
                    break;
                }
            }
        }
    }
}

#[test]
fn peer_stream_with_credits_survives_chunking_and_corruption() {
    // What a mesh peer connection actually carries: interleaved Packet
    // frames on both packet lanes plus 4-byte Credit returns, decoded
    // through arbitrary partial reads. The framer must reproduce the
    // exact frame sequence (any desync would stall or corrupt the credit
    // window), and a flipped byte must surface as a clean error — the
    // mesh drops the connection, it never resynchronizes silently.
    let mut rng = Rng::new(0x3E5CED17);
    for round in 0..60 {
        let mut stream = Vec::new();
        let mut want = Vec::new();
        let mut buf = Vec::new();
        for _ in 0..(2 + round % 6) {
            let (lane, kind, body) = match rng.below(4) {
                0 => (Lane::Fwd, FrameKind::Packet, {
                    let len = 1 + rng.below(400) as usize;
                    (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
                }),
                1 => (Lane::Bwd, FrameKind::Packet, {
                    let len = 1 + rng.below(400) as usize;
                    (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
                }),
                2 => (Lane::Fwd, FrameKind::Credit, 1u32.to_le_bytes().to_vec()),
                _ => (Lane::Bwd, FrameKind::Credit, (rng.below(8) as u32).to_le_bytes().to_vec()),
            };
            encode_frame(lane, kind, &body, &mut buf);
            stream.extend_from_slice(&buf);
            want.push((lane, kind, body));
        }

        // Clean pass under adversarial chunking: byte-exact reproduction.
        let mut fr = Framer::new();
        let mut got = Vec::new();
        let mut pos = 0usize;
        while pos < stream.len() {
            let end = (pos + 1 + rng.below(61) as usize).min(stream.len());
            fr.push(&stream[pos..end]);
            pos = end;
            while let Some(f) = fr.next().expect("clean peer stream must decode") {
                got.push((f.lane, f.kind, f.body));
            }
        }
        assert_eq!(got, want, "round {round}: peer stream desynced");

        // Corrupted pass: one flipped byte errors cleanly, never panics.
        let i = rng.below(stream.len() as u64) as usize;
        stream[i] ^= 1 << rng.below(8);
        let mut fr = Framer::new();
        fr.push(&stream);
        let mut decoded = 0usize;
        loop {
            match fr.next() {
                Ok(Some(_)) => decoded += 1,
                Ok(None) => break,
                Err(_) => break,
            }
        }
        assert!(decoded <= want.len(), "corruption invented frames");
    }
}

#[test]
fn version_mismatch_and_checksum_are_both_detected() {
    let mut buf = Vec::new();
    encode_frame(Lane::Driver, FrameKind::Heartbeat, &[1, 2, 3, 4], &mut buf);

    // Version skew: flip the version byte and fix the checksum so ONLY
    // the version check can catch it.
    let mut skewed = buf.clone();
    skewed[1] = FRAME_VERSION + 7;
    let n = skewed.len();
    let sum = fnv1a64(&skewed[..n - 8]);
    skewed[n - 8..].copy_from_slice(&sum.to_le_bytes());
    let mut fr = Framer::new();
    fr.push(&skewed);
    assert!(fr.next().unwrap_err().to_string().contains("version"));

    // Checksum: flip a body bit, leave the checksum alone.
    let mut flipped = buf.clone();
    let n = flipped.len();
    flipped[n - 9] ^= 0x80;
    let mut fr = Framer::new();
    fr.push(&flipped);
    assert!(fr.next().unwrap_err().to_string().contains("checksum"));
}
