//! Churn-tolerance integration tests — no PJRT artifacts needed: the
//! Null compute backend mocks the math while the *real* broker runs
//! heartbeats, the deadline monitor, boundary checkpoints, the churn
//! injector, failover re-planning and checkpoint restore over real
//! threads and channels.

use fusionllm::broker::{self, ChurnTrace, Job};
use fusionllm::checkpoint;
use fusionllm::scheduler::replan::ReplanMode;
use fusionllm::worker::BackendKind;
use std::path::PathBuf;

fn ckpt_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fusionllm-churn-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// A fast artifact-free job: 4 Null stages pinned to devices 0..4,
/// 20 ms heartbeats with a 1 s death deadline.
fn null_job(tag: &str) -> Job {
    Job {
        config: "churn-test".into(),
        backend: BackendKind::Null,
        iters: 8,
        n_micro: 2,
        placement: Some(vec![0, 1, 2, 3]),
        // Crash recovery only; Null compute times are too noisy for
        // meaningful straggler detection.
        straggler_threshold: 1e9,
        // 1 s death deadline: tests run in parallel; a descheduled live
        // thread must not be misdeclared dead.
        heartbeat_s: 0.02,
        heartbeat_timeout: 50,
        checkpoint_every: 2,
        checkpoint_dir: ckpt_dir(tag),
        ..Job::default()
    }
}

#[test]
fn killed_run_recovers_and_matches_unkilled() {
    // Device 1 (stage 1) vanishes at the top of iteration 3. The broker
    // must detect the death, re-plan around the device, restore the
    // iteration-2 checkpoint, rewind the data loader, and finish all 8
    // iterations with a loss trajectory bitwise-equal to an uninterrupted
    // run (determinism satellite).
    let base = null_job("determinism");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        kill_device: Some(1),
        kill_at_iter: 3,
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 8, "all iterations must complete");
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!(r.stage, 1);
    assert_eq!(r.device, 1);
    assert_eq!(r.died_iter, 3);
    assert_eq!(r.resume_iter, 2, "newest checkpoint is the iter-2 boundary");
    assert_eq!(r.iters_lost, 1);
    assert_eq!(r.from, vec![0, 1, 2, 3]);
    assert!(!r.to.contains(&1), "dead device still placed: {:?}", r.to);
    assert!(r.replan_s >= 0.0 && r.restore_s >= 0.0);
    // Final placement reflects the failover.
    assert_eq!(churn.placement, r.to);
    // Kill-and-recover must not change the numbers: checkpoint restore +
    // corpus rewind re-run iterations 2..8 deterministically.
    assert_eq!(clean.losses.len(), churn.losses.len());
    for (i, (a, b)) in clean.losses.iter().zip(&churn.losses).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "iter {i}: clean {a} != recovered {b}"
        );
    }
}

#[test]
fn two_concurrent_kills_recover_in_one_pass() {
    // Devices 1 and 2 vanish at the top of the same iteration. The
    // deadline monitor declares the first death, the settle window sweeps
    // up the second, and a single failover re-plan dodges *both* corpses
    // — two RecoveryEvents, one restore, all iterations, bitwise losses
    // (the pinned cascading-failure case).
    let base = null_job("twokill");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        churn: Some(ChurnTrace::parse("kill 1 @3\nkill 2 @3").unwrap()),
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 8, "all iterations must complete");
    assert_eq!(churn.recoveries.len(), 2, "{:?}", churn.recoveries);
    let devs: Vec<usize> = churn.recoveries.iter().map(|r| r.device).collect();
    assert!(devs.contains(&1) && devs.contains(&2), "wrong corpses: {devs:?}");
    for r in &churn.recoveries {
        assert_eq!(r.died_iter, 3);
        assert_eq!(r.resume_iter, 2, "both resume from the iter-2 boundary");
        assert!(
            !r.to.contains(&1) && !r.to.contains(&2),
            "failover placement still uses a dead device: {:?}",
            r.to
        );
    }
    assert!(churn.joins.is_empty());
    for (i, (a, b)) in clean.losses.iter().zip(&churn.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "iter {i}: clean {a} != recovered {b}");
    }
}

#[test]
fn death_at_checkpoint_boundary_discards_partial_snapshot() {
    // Device 1 dies exactly at the iter-4 checkpoint boundary: its stage
    // never answers the `Wire::Checkpoint` broadcast, so the collection
    // must abort, DISCARD the partial snapshot (no ckpt-00000004 from the
    // doomed pass, no .tmp- residue), and recover from the intact iter-2
    // version.
    let base = null_job("ckptdeath");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        kill_device: Some(1),
        kill_at_iter: 4,
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    assert_eq!(churn.losses.len(), 8);
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    let r = &churn.recoveries[0];
    assert_eq!(r.died_iter, 4);
    assert_eq!(
        r.resume_iter, 2,
        "the interrupted iter-4 snapshot must be discarded, not restored"
    );
    assert_eq!(r.iters_lost, 2);
    // Only complete, atomically-renamed versions on disk — the re-run
    // after recovery rewrites boundaries 4 and 6 cleanly.
    let entries: Vec<String> = std::fs::read_dir(&base.checkpoint_dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    assert!(
        entries.iter().all(|n| n.starts_with("ckpt-")),
        "partial checkpoint residue: {entries:?}"
    );
    assert_eq!(checkpoint::versions(&base.checkpoint_dir), vec![2, 4, 6]);
    for (a, b) in clean.losses.iter().zip(&churn.losses) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
}

#[test]
fn mid_run_join_is_admitted_at_the_scripted_boundary() {
    // A brand-new device (9: an Rtx2080, strictly slower than the four
    // Rtx4090s already hosting stages) becomes available at iteration 5.
    // It must be admitted and recorded; the re-planner only folds it in
    // when the simnet predicts a win, so a slower newcomer stays parked
    // and the placement is untouched. Either way the math cannot move.
    let base = null_job("join");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        churn: Some(ChurnTrace::parse("join 9 @5").unwrap()),
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 8);
    assert!(churn.recoveries.is_empty(), "{:?}", churn.recoveries);
    assert_eq!(churn.joins.len(), 1, "{:?}", churn.joins);
    let j = &churn.joins[0];
    assert_eq!((j.device, j.kind.as_str(), j.iter), (9, "join", 5));
    if !j.adopted {
        assert_eq!(j.from, j.to, "a parked spare must not move the placement");
        assert_eq!(j.sim_before_s.to_bits(), j.sim_after_s.to_bits());
    }
    for (a, b) in clean.losses.iter().zip(&churn.losses) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn killed_device_rejoins_after_recovery() {
    // kill 1 @3, rejoin 1 @5: the device dies, the run recovers onto
    // survivors, then the same device reconnects two iterations later.
    // The rejoin is admitted as a fresh spare (liveness re-earned) and —
    // because device 1 is an Rtx4090 displaced by a slower survivor —
    // typically re-adopted by the join re-planner. Losses stay bitwise
    // either way.
    let base = null_job("rejoin");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        churn: Some(ChurnTrace::parse("kill 1 @3\nrejoin 1 @5").unwrap()),
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);

    assert_eq!(churn.losses.len(), 8);
    assert_eq!(churn.recoveries.len(), 1, "{:?}", churn.recoveries);
    assert_eq!(churn.recoveries[0].device, 1);
    assert_eq!(churn.joins.len(), 1, "{:?}", churn.joins);
    let j = &churn.joins[0];
    assert_eq!((j.device, j.kind.as_str()), (1, "rejoin"));
    assert!(j.iter >= 5, "admitted at the first boundary >= the scripted iter");
    if j.adopted {
        assert!(j.to.contains(&1), "adopted rejoin must host a stage: {:?}", j.to);
    }
    for (i, (a, b)) in clean.losses.iter().zip(&churn.losses).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "iter {i}: clean {a} != churned {b}");
    }
}

#[test]
fn recovery_without_checkpoints_restarts_from_scratch() {
    // No checkpointing: recovery still works, resuming from iteration 0
    // with fresh state — losing more work but staying deterministic.
    let base = null_job("nockpt");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        checkpoint_every: 0,
        iters: 5,
        kill_device: Some(2),
        kill_at_iter: 2,
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    assert_eq!(churn.losses.len(), 5);
    assert_eq!(churn.recoveries.len(), 1);
    let r = &churn.recoveries[0];
    assert_eq!((r.resume_iter, r.died_iter, r.iters_lost), (0, 2, 2));
    for (a, b) in clean.losses.iter().zip(&churn.losses) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn death_without_replan_auto_aborts_with_joined_threads() {
    // replan off: the death must surface as an error (pointing at
    // --replan auto), not a hang — and the generation's threads are
    // joined before the error returns.
    let base = null_job("abort");
    let err = broker::run(&Job {
        kill_device: Some(1),
        kill_at_iter: 3,
        replan: ReplanMode::Off,
        ..base.clone()
    })
    .unwrap_err();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
    let msg = format!("{err:#}");
    assert!(
        msg.contains("crash recovery requires --replan auto"),
        "unexpected error: {msg}"
    );
}

#[test]
fn on_disk_checkpoints_version_and_fall_back_when_corrupted() {
    // A healthy run leaves versioned checkpoints behind; corrupting the
    // newest stage file makes restore fall back to the previous version
    // (manifest integrity end-to-end, on files the broker really wrote).
    let base = null_job("fallback");
    let report = broker::run(&base).unwrap();
    assert_eq!(report.losses.len(), 8);
    assert!(report.recoveries.is_empty());
    // Versions after the first ride the incremental path: the report's
    // delta accounting must show real savings over full snapshots.
    assert!(
        report.checkpoint_bytes_delta > 0.0
            && report.checkpoint_bytes_delta < report.checkpoint_bytes_full,
        "delta {} vs full {}",
        report.checkpoint_bytes_delta,
        report.checkpoint_bytes_full
    );
    let vs = checkpoint::versions(&base.checkpoint_dir);
    assert_eq!(vs, vec![2, 4, 6], "boundary checkpoints at 2/4/6: {vs:?}");
    assert_eq!(
        checkpoint::load_latest(&base.checkpoint_dir).unwrap().unwrap().iter,
        6
    );
    // Corrupt the newest version's stage-2 payload.
    let victim = base.checkpoint_dir.join("ckpt-00000006/stage-2.bin");
    let mut bytes = std::fs::read(&victim).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x5A;
    std::fs::write(&victim, &bytes).unwrap();
    let ck = checkpoint::load_latest(&base.checkpoint_dir)
        .unwrap()
        .expect("previous version survives");
    assert_eq!(ck.iter, 4, "restore must fall back past the corrupt version");
    assert_eq!(ck.config, "churn-test");
    assert_eq!(ck.placement, vec![0, 1, 2, 3]);
    assert_eq!(ck.states.len(), 4);
    // Null stages snapshot the scalar parameter plus the 1024-slot bulk
    // block (the realistic-sized state that makes delta layers earn their
    // keep).
    assert!(ck.states.iter().all(|s| s.params.len() == 1025));
    assert_eq!(ck.corpus_batches, 8, "4 iterations x 2 microbatches fed");
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
}

#[test]
fn null_backend_runs_clean_without_liveness_plane() {
    // Heartbeats off (the PR 3 blocking path) must still work for a
    // healthy run — and checkpointing without heartbeats is rejected
    // rather than deadlocking.
    let base = null_job("nohb");
    let r = broker::run(&Job {
        heartbeat_s: 0.0,
        checkpoint_every: 0,
        ..base.clone()
    })
    .unwrap();
    assert_eq!(r.losses.len(), 8);
    assert!(r.losses.iter().all(|l| l.is_finite()));
    let err = broker::run(&Job {
        heartbeat_s: 0.0,
        ..base.clone()
    })
    .unwrap_err();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
    assert!(format!("{err:#}").contains("requires heartbeats"));
}

#[test]
fn head_stage_death_recovers_from_late_checkpoint() {
    // Killing the *head* stage exercises the harder detection path: its
    // upstream neighbor quiesces on a failed send, the driver stops
    // receiving losses, and the deadline monitor must still attribute the
    // death to the right stage. A later kill also verifies restore picks
    // the newest of several checkpoint versions.
    let base = null_job("late");
    let clean = broker::run(&Job {
        checkpoint_every: 0,
        iters: 12,
        ..base.clone()
    })
    .unwrap();
    let churn = broker::run(&Job {
        iters: 12,
        kill_device: Some(3),
        kill_at_iter: 9,
        replan: ReplanMode::Auto,
        ..base.clone()
    })
    .unwrap();
    let _ = std::fs::remove_dir_all(&base.checkpoint_dir);
    assert_eq!(churn.losses.len(), 12);
    assert_eq!(churn.recoveries.len(), 1);
    let r = &churn.recoveries[0];
    assert_eq!(r.resume_iter, 8, "newest boundary before the death");
    assert_eq!(r.iters_lost, 1);
    assert_eq!(r.stage, 3, "head stage death must also recover");
    for (a, b) in clean.losses.iter().zip(&churn.losses) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
