//! The schedule interpreter, exercised end-to-end over real channels and
//! wire codecs with the mock `NullBackend` (no PJRT artifacts needed):
//!
//!   * sim-vs-worker agreement — the interpreter executes every task of
//!     its `PipelineSchedule` row exactly once, in schedule order;
//!   * GPipe and 1F1B produce bitwise-identical loss trajectories (the
//!     fixed per-micro grad-accumulation order contract);
//!   * stateful property test — randomized legal schedules (full-flush
//!     with a shared backward permutation; 1F1B-style with randomized
//!     non-increasing warmup depths) over random `n_stages × n_micro`
//!     execute without deadlock and with Forward-before-Backward per
//!     micro (inspired by proptest-stateful's plan-then-execute shape,
//!     hand-rolled on `util::rng` — no proptest dep offline).

use fusionllm::compress::CompressPlan;
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind, Task, TaskKind};
use fusionllm::transport::chan;
use fusionllm::util::rng::Rng;
use fusionllm::worker::{run_schedule, NullBackend, StageCodec, StageLinks, Wire};
use std::sync::mpsc;
use std::time::Duration;

/// Generous per-message bound; a deadlocked pipeline trips this.
const TIMEOUT: Duration = Duration::from_secs(30);

struct RunResult {
    /// Summed per-iteration loss (n_micro microbatch losses each).
    losses: Vec<f32>,
    /// Per-stage executed (kind, micro) log, in execution order.
    logs: Vec<Vec<(TaskKind, usize)>>,
    /// IterProfile messages observed.
    profiles: usize,
}

/// Build the broker's channel topology for `schedule`, run every stage on
/// the production interpreter with a `NullBackend`, drive `iters`
/// iterations of synthetic data, and collect the results.
fn run_pipeline(schedule: &PipelineSchedule, iters: usize, n: usize) -> RunResult {
    let s_n = schedule.n_stages;
    let n_micro = schedule.n_micro;
    let plan = CompressPlan::dense(s_n.max(1));
    let (tx_driver, rx_driver) = mpsc::channel::<Wire>();
    let mut fwd_tx = Vec::new();
    let mut fwd_rx = Vec::new();
    let mut bwd_tx = Vec::new();
    let mut bwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        fwd_tx.push(t);
        fwd_rx.push(Some(r));
        let (t, r) = mpsc::channel::<Wire>();
        bwd_tx.push(t);
        bwd_rx.push(Some(r));
    }
    let (label_tx, label_rx) = mpsc::channel::<Wire>();
    let mut label_rx = Some(label_rx);

    let mut handles = Vec::new();
    for s in 0..s_n {
        let next = if s + 1 < s_n { Some(s + 1) } else { None };
        let prev = if s > 0 { Some(s - 1) } else { None };
        let mut links = StageLinks {
            stage: s,
            device: s,
            codec: StageCodec::from_plan(&plan, next, prev, n.max(1)),
            rx_fwd: chan::endpoint(fwd_rx[s].take().unwrap()),
            rx_bwd: if s + 1 < s_n {
                bwd_rx[s].take().map(chan::endpoint)
            } else {
                None
            },
            tx_fwd: if s + 1 < s_n { Some(chan::link(fwd_tx[s + 1].clone())) } else { None },
            tx_bwd: if s > 0 { Some(chan::link(bwd_tx[s - 1].clone())) } else { None },
            rx_labels: if s == s_n - 1 { label_rx.take().map(chan::endpoint) } else { None },
            tx_driver: chan::link(tx_driver.clone()),
            fwd_return: None,
            bwd_return: None,
        };
        let tasks = schedule.tasks[s].clone();
        let is_head = s == s_n - 1;
        handles.push(std::thread::spawn(move || {
            let mut backend = NullBackend::new(n, n_micro, is_head);
            run_schedule(&mut links, &mut backend, &tasks, 0, iters).map(|_| backend.log)
        }));
    }
    drop(tx_driver);
    drop(bwd_tx);

    // Feed every iteration's data + labels upfront (channels buffer).
    for it in 0..iters as u32 {
        for m in 0..n_micro as u32 {
            let tokens: Vec<i32> =
                (0..n as i32).map(|i| (i % 7) + it as i32 + m as i32).collect();
            fwd_tx[0].send(Wire::Data { iter: it, micro: m, tokens }).unwrap();
            label_tx
                .send(Wire::Labels { iter: it, micro: m, targets: vec![0; 4] })
                .unwrap();
        }
    }

    let mut losses = vec![0.0f32; iters];
    let mut profiles = 0usize;
    let mut stats_seen = 0usize;
    while stats_seen < s_n {
        match rx_driver.recv_timeout(TIMEOUT) {
            Ok(Wire::Loss { iter, loss, .. }) => losses[iter as usize] += loss,
            Ok(Wire::IterProfile { .. }) => profiles += 1,
            Ok(Wire::Stats(_)) => stats_seen += 1,
            Ok(Wire::Fatal { stage, error }) => panic!("stage {stage} failed: {error}"),
            Ok(other) => panic!("driver got unexpected {other:?}"),
            Err(_) => panic!(
                "pipeline deadlock/timeout (stages={s_n} micros={n_micro}, \
                 stats {stats_seen}/{s_n})"
            ),
        }
    }
    let logs: Vec<Vec<(TaskKind, usize)>> = handles
        .into_iter()
        .map(|h| h.join().expect("worker panicked").expect("worker errored"))
        .collect();
    RunResult { losses, logs, profiles }
}

/// The schedule row as the interpreter should have executed it.
fn expected_log(schedule: &PipelineSchedule, stage: usize, iters: usize) -> Vec<(TaskKind, usize)> {
    let one: Vec<(TaskKind, usize)> = schedule.tasks[stage]
        .iter()
        .map(|t| match t.kind {
            TaskKind::Update => (TaskKind::Update, 0),
            k => (k, t.micro),
        })
        .collect();
    let mut out = Vec::new();
    for _ in 0..iters {
        out.extend(one.iter().copied());
    }
    out
}

#[test]
fn interpreter_executes_every_task_exactly_once_in_schedule_order() {
    // The sim-vs-worker agreement contract: what `simnet` simulates is
    // literally what the workers execute.
    for kind in [ScheduleKind::GPipe, ScheduleKind::OneFOneB] {
        for (s_n, n_m) in [(1, 2), (2, 3), (3, 4), (4, 2)] {
            let schedule = PipelineSchedule::new(kind, s_n, n_m);
            schedule.validate().unwrap();
            let iters = 2;
            let r = run_pipeline(&schedule, iters, 32);
            assert_eq!(r.profiles, s_n * iters, "{kind:?} {s_n}x{n_m}: profiles");
            for s in 0..s_n {
                assert_eq!(
                    r.logs[s],
                    expected_log(&schedule, s, iters),
                    "{kind:?} stage {s}/{s_n} n_micro={n_m}: execution order \
                     diverged from the schedule"
                );
            }
            assert!(r.losses.iter().all(|l| l.is_finite()));
        }
    }
}

#[test]
fn gpipe_and_1f1b_mock_losses_bitwise_equal() {
    // Fixed per-micro accumulation order => schedule-independent numerics.
    for (s_n, n_m) in [(2, 4), (3, 3), (4, 8)] {
        let g = run_pipeline(&PipelineSchedule::new(ScheduleKind::GPipe, s_n, n_m), 4, 64);
        let o =
            run_pipeline(&PipelineSchedule::new(ScheduleKind::OneFOneB, s_n, n_m), 4, 64);
        assert_eq!(
            g.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            o.losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            "{s_n}x{n_m}: gpipe {:?} vs 1f1b {:?}",
            g.losses,
            o.losses
        );
    }
}

/// Full-flush schedule: ascending forwards, one random backward
/// permutation shared by every stage (GPipe = the descending case).
fn flush_schedule(n_s: usize, n_m: usize, rng: &mut Rng) -> PipelineSchedule {
    let mut order: Vec<usize> = (0..n_m).collect();
    rng.shuffle(&mut order);
    let tasks = (0..n_s)
        .map(|s| {
            let mut v: Vec<Task> = (0..n_m)
                .map(|m| Task { stage: s, micro: m, kind: TaskKind::Forward })
                .collect();
            v.extend(
                order.iter().map(|&m| Task { stage: s, micro: m, kind: TaskKind::Backward }),
            );
            v.push(Task { stage: s, micro: 0, kind: TaskKind::Update });
            v
        })
        .collect();
    PipelineSchedule { kind: ScheduleKind::GPipe, n_stages: n_s, n_micro: n_m, tasks }
}

/// 1F1B-style schedule with randomized warmup depths: stage s runs
/// `w[s]` forwards before its first backward, then alternates 1B1F.
/// Deadlock-freedom needs `w[s] >= w[s+1]` (a stage must have produced
/// enough activations for its successor's warmup before blocking on a
/// gradient); within that constraint the depths are random.
fn warmup_schedule(n_s: usize, n_m: usize, rng: &mut Rng) -> PipelineSchedule {
    let mut w = vec![1usize; n_s];
    let mut lo = 1usize;
    for s in (0..n_s).rev() {
        let pick = lo + rng.below((n_m - lo + 1) as u64) as usize;
        w[s] = pick.min(n_m);
        lo = w[s];
    }
    let tasks = (0..n_s)
        .map(|s| {
            let mut v = Vec::with_capacity(2 * n_m + 1);
            let mut f = 0usize;
            let mut b = 0usize;
            for _ in 0..w[s] {
                v.push(Task { stage: s, micro: f, kind: TaskKind::Forward });
                f += 1;
            }
            while b < n_m {
                v.push(Task { stage: s, micro: b, kind: TaskKind::Backward });
                b += 1;
                if f < n_m {
                    v.push(Task { stage: s, micro: f, kind: TaskKind::Forward });
                    f += 1;
                }
            }
            v.push(Task { stage: s, micro: 0, kind: TaskKind::Update });
            v
        })
        .collect();
    PipelineSchedule { kind: ScheduleKind::OneFOneB, n_stages: n_s, n_micro: n_m, tasks }
}

#[test]
fn random_legal_schedules_execute_without_deadlock() {
    // Stateful property test: generate a random legal schedule, validate
    // it structurally, execute it on the real interpreter, then check the
    // observed logs for exactly-once and fwd-before-bwd per micro.
    let mut rng = Rng::new(0x5EED);
    for case in 0..12u32 {
        let n_s = 1 + rng.below(4) as usize;
        let n_m = 1 + rng.below(6) as usize;
        let schedule = if case % 2 == 0 {
            flush_schedule(n_s, n_m, &mut rng)
        } else {
            warmup_schedule(n_s, n_m, &mut rng)
        };
        schedule
            .validate()
            .unwrap_or_else(|e| panic!("case {case} ({n_s}x{n_m}) invalid: {e}"));
        let r = run_pipeline(&schedule, 1, 16);
        for (s, log) in r.logs.iter().enumerate() {
            assert_eq!(log.len(), 2 * n_m + 1, "case {case} stage {s}");
            for m in 0..n_m {
                let f = log.iter().position(|&t| t == (TaskKind::Forward, m));
                let b = log.iter().position(|&t| t == (TaskKind::Backward, m));
                let (f, b) = (
                    f.unwrap_or_else(|| panic!("case {case} stage {s}: no fwd {m}")),
                    b.unwrap_or_else(|| panic!("case {case} stage {s}: no bwd {m}")),
                );
                assert!(f < b, "case {case} stage {s}: bwd {m} before fwd");
                // Exactly once: no second occurrence.
                assert!(!log[f + 1..].contains(&(TaskKind::Forward, m)));
                assert!(!log[b + 1..].contains(&(TaskKind::Backward, m)));
            }
            assert_eq!(*log.last().unwrap(), (TaskKind::Update, 0));
        }
        assert!(r.losses[0].is_finite());
    }
}

#[test]
fn peak_stash_matches_execution_for_random_warmups() {
    // The schedule's static peak_stash must match what a live stage would
    // hold — checked against the warmup structure (w forwards live before
    // the first backward frees one).
    let mut rng = Rng::new(7);
    for _ in 0..8 {
        let n_s = 1 + rng.below(4) as usize;
        let n_m = 1 + rng.below(6) as usize;
        let schedule = warmup_schedule(n_s, n_m, &mut rng);
        for s in 0..n_s {
            let warmup = schedule.tasks[s]
                .iter()
                .take_while(|t| t.kind == TaskKind::Forward)
                .count();
            assert_eq!(schedule.peak_stash(s), warmup.min(n_m), "stage {s}");
        }
    }
}
