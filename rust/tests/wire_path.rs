//! Differential property tests for the allocation-free, multi-core wire
//! path (proptest-lite style: seeded generators + many trials).
//!
//! Invariants defended:
//!   * `compress_into` ≡ `compress` for every compressor and payload shape
//!     (0, 1, ragged chunks, all-duplicates, >512-element radix path)
//!   * the parallel radix select + gather is bit-identical across thread
//!     counts 1/2/8 (per-thread partitions stitch in index order), and so
//!     is the int8-quantized combined encoding built on top of it
//!   * `encode_into` ≡ `encode`, and `OpDataView` ≡ `OpData::decode`
//!   * `LinkEncoder` (steady-state, scratch-reusing) ≡ `encode_payload`
//!     under both value codecs (f32 and int8)
//!   * int8+Top-K wire round trip stays within half a scale step of the
//!     f32 path and costs ≤ 5 B per kept value on the packet

use fusionllm::compress::{
    ChunkedTopK, CompressKind, CompressScratch, Compressed, Compressor, Int8Quantizer,
    NoCompress, Quantized, RandomK, TopK, ValueCodec,
};
use fusionllm::opdag::data::{CompressCfg, OpData, OpDataKind, OpDataView};
use fusionllm::util::math::kth_largest_abs_threads;
use fusionllm::util::rng::Rng;
use fusionllm::worker::messages::encode_payload_with;
use fusionllm::worker::{decode_payload, decode_payload_into, LinkEncoder};

/// Payload shapes covering every special case in the select/gather paths.
fn payload_shapes(rng: &mut Rng) -> Vec<Vec<f32>> {
    let mut shapes: Vec<Vec<f32>> = vec![
        vec![],                  // empty
        vec![0.25],              // single element
        vec![1.0; 100],          // small, all duplicates
        vec![-2.5; 4096],        // all duplicates, radix path
        (0..150).map(|_| rng.f32() - 0.5).collect(), // ragged vs chunk=64
        (0..511).map(|_| rng.f32() - 0.5).collect(), // sort-path boundary
        (0..513).map(|_| rng.f32() - 0.5).collect(), // radix-path boundary
        (0..5000).map(|_| (rng.f32() - 0.5) * 1e-3).collect(), // tight exponents
        (0..100_000).map(|_| rng.f32() - 0.5).collect(), // parallel path
    ];
    // Plateau + spikes: strictly-above entries AND many threshold ties, so
    // the tie-merge path runs under the parallel gather.
    let mixed: Vec<f32> = (0..40_000)
        .map(|i| match i % 10 {
            0 => 5.0 + rng.f32(),
            1 => 1.0,
            _ => rng.f32() * 0.9,
        })
        .collect();
    shapes.push(mixed);
    shapes
}

fn assert_compressed_eq(a: &Compressed, b: &Compressed, ctx: &str) {
    assert_eq!(a.cfg, b.cfg, "{ctx}: cfg");
    assert_eq!(a.values, b.values, "{ctx}: values");
    assert_eq!(a.indices, b.indices, "{ctx}: indices");
    assert_eq!(a.bytes, b.bytes, "{ctx}: bytes");
}

#[test]
fn prop_compress_into_equals_compress_for_all_impls() {
    let mut rng = Rng::new(0x1A70);
    let comps: [&dyn Compressor; 11] = [
        &NoCompress,
        &TopK { ratio: 100.0 },
        &TopK { ratio: 3.0 },
        &ChunkedTopK { ratio: 8.0, chunk: 64 },
        &ChunkedTopK { ratio: 100.0, chunk: 1600 },
        &RandomK { ratio: 50.0, seed: 7 },
        &Int8Quantizer,
        &Quantized { inner: TopK { ratio: 100.0 }, row: None },
        &Quantized { inner: ChunkedTopK { ratio: 8.0, chunk: 64 }, row: Some(64) },
        &Quantized { inner: RandomK { ratio: 50.0, seed: 7 }, row: None },
        &Quantized { inner: NoCompress, row: None },
    ];
    for data in payload_shapes(&mut rng) {
        for comp in comps {
            let oracle = comp.compress(&data);
            let mut into = Compressed::default();
            comp.compress_into(&data, &mut into);
            let ctx = format!("{} n={}", comp.name(), data.len());
            assert_compressed_eq(&oracle, &into, &ctx);
            // Reuse the same output + scratch for a second pass: identical.
            let mut scratch = CompressScratch::default();
            comp.compress_with(&data, &mut into, &mut scratch);
            comp.compress_with(&data, &mut into, &mut scratch);
            assert_compressed_eq(&oracle, &into, &format!("{ctx} (reused)"));
        }
    }
}

#[test]
fn prop_parallel_compress_deterministic_across_thread_counts() {
    let mut rng = Rng::new(0xDE7E);
    for data in payload_shapes(&mut rng) {
        if data.is_empty() {
            continue;
        }
        for ratio in [3.0, 100.0] {
            // Threshold is bit-identical for 1/2/8 worker threads...
            let topk = TopK { ratio };
            let k = topk.k_for(data.len());
            let t1 = kth_largest_abs_threads(&data, k, 1);
            let t2 = kth_largest_abs_threads(&data, k, 2);
            let t8 = kth_largest_abs_threads(&data, k, 8);
            assert_eq!(t1.to_bits(), t2.to_bits(), "n={} r={ratio}", data.len());
            assert_eq!(t1.to_bits(), t8.to_bits(), "n={} r={ratio}", data.len());
            // ...and so is the full compressed (values, indices) pair —
            // including the int8-quantized post-pass (a sequential pass,
            // so the combined encoding inherits the determinism).
            for comp in [
                &ChunkedTopK { ratio, chunk: 1600 } as &dyn Compressor,
                &topk as &dyn Compressor,
                &Quantized { inner: ChunkedTopK { ratio, chunk: 1600 }, row: Some(1600) }
                    as &dyn Compressor,
                &Quantized { inner: topk, row: None } as &dyn Compressor,
            ] {
                let mut base = Compressed::default();
                comp.compress_with(&data, &mut base, &mut CompressScratch::with_threads(1));
                for threads in [2usize, 8] {
                    let mut out = Compressed::default();
                    comp.compress_with(
                        &data,
                        &mut out,
                        &mut CompressScratch::with_threads(threads),
                    );
                    let ctx =
                        format!("{} n={} r={ratio} threads={threads}", comp.name(), data.len());
                    assert_compressed_eq(&base, &out, &ctx);
                }
            }
        }
    }
}

#[test]
fn prop_encode_into_equals_encode_and_view_equals_decode() {
    let mut rng = Rng::new(0xE2C0);
    let mut reused = Vec::new();
    for trial in 0..200 {
        let np = match trial % 4 {
            0 => 0,
            1 => 1,
            _ => rng.below(3000) as usize,
        };
        let ni = if trial % 3 == 0 { np } else { rng.below(500) as usize };
        let nb = rng.below(300) as usize;
        let mut od = OpData::dense(
            rng.below(1000) as usize,
            rng.below(1000) as usize,
            if rng.f64() < 0.5 { OpDataKind::Activation } else { OpDataKind::Gradient },
            rng.below(u32::MAX as u64) as u32,
            rng.below(64) as u32,
            (0..np).map(|_| rng.f32() - 0.5).collect(),
        );
        od.indices = (0..ni).map(|_| rng.below(1 << 20) as u32).collect();
        od.bytes_payload = (0..nb).map(|_| rng.below(256) as u8).collect();
        od.is_loss = rng.f64() < 0.5;
        od.compress = match trial % 4 {
            0 => CompressCfg::None,
            1 => CompressCfg::TopK { ratio: rng.f64() * 100.0, total_len: 1 << 20 },
            2 => CompressCfg::RandomK {
                ratio: rng.f64() * 100.0,
                total_len: 1 << 20,
                seed: rng.next_u64(),
            },
            _ => CompressCfg::Int8 { scale: rng.f32(), total_len: nb as u32 },
        };

        // encode_into (reused buffer) must be byte-identical to encode.
        let fresh = od.encode();
        od.encode_into(&mut reused);
        assert_eq!(fresh, reused, "trial {trial}");

        // The zero-copy view must agree with the owned decode.
        let v = OpDataView::parse(&fresh).unwrap();
        let back = OpData::decode(&fresh).unwrap();
        assert_eq!(v.header.src_op, back.src_op, "trial {trial}");
        assert_eq!(v.header.dst_op, back.dst_op);
        assert_eq!(v.header.actual_user, back.actual_user);
        assert_eq!(v.header.kind, back.kind);
        assert_eq!(v.header.is_loss, back.is_loss);
        assert_eq!(v.header.require_grad, back.require_grad);
        assert_eq!(v.header.local_iter, back.local_iter);
        assert_eq!(v.header.micro_batch, back.micro_batch);
        assert_eq!(v.compress, back.compress);
        assert_eq!(v.payload_iter().collect::<Vec<_>>(), back.payload);
        assert_eq!(v.indices_iter().collect::<Vec<_>>(), back.indices);
        assert_eq!(v.bytes_payload(), &back.bytes_payload[..]);
    }
}

/// Tentpole precision contract: quantize → encode → view-decode →
/// dequantize lands within half a scale step (+1 ULP slack) of the direct
/// f32 compress on every payload shape, with identical support.
#[test]
fn prop_quantized_wire_roundtrip_within_one_ulp_of_scale() {
    let mut rng = Rng::new(0x178_1234);
    for data in payload_shapes(&mut rng) {
        if data.is_empty() {
            continue;
        }
        let chunk = 64usize;
        let plain = ChunkedTopK { ratio: 8.0, chunk };
        let quant = Quantized { inner: plain, row: Some(chunk) };
        // Direct f32 compress+decompress (the oracle).
        let mut want = vec![0.0f32; data.len()];
        plain.decompress(&plain.compress(&data), &mut want);
        // Quantized path through the real wire: encode -> view -> scatter.
        let c = quant.compress(&data);
        let mut od = OpData::dense(0, 1, OpDataKind::Gradient, 0, 0, c.values.clone());
        od.indices = c.indices.clone();
        od.bytes_payload = c.bytes.clone();
        od.compress = c.cfg.clone();
        let buf = od.encode();
        let mut got = vec![f32::NAN; data.len()];
        decode_payload_into(&buf, &mut got).unwrap();
        let scales = match &c.cfg {
            CompressCfg::QSparseRows { .. } => &c.values,
            other => panic!("expected QSparseRows, got {other:?}"),
        };
        for (i, (&w, &g)) in want.iter().zip(&got).enumerate() {
            if w == 0.0 {
                assert_eq!(g, 0.0, "support mismatch at {i} (n={})", data.len());
            } else {
                let s = scales[i / chunk];
                assert!(
                    (w - g).abs() <= s * (0.5 + 1e-4),
                    "idx {i}: {w} vs {g}, scale {s} (n={})",
                    data.len()
                );
            }
        }
        // And the in-memory decompress agrees with the wire decode.
        let mut mem = vec![0.0f32; data.len()];
        quant.decompress(&c, &mut mem);
        assert_eq!(mem, got, "n={}", data.len());
    }
}

/// Acceptance: the combined int8+Top-K encoding costs ≤ 5 bytes per kept
/// value (+ constant header/cfg overhead) on the encoded packet, vs 8 for
/// the f32-sparse wire layout.
#[test]
fn int8_sparse_packet_is_at_most_five_bytes_per_value() {
    let mut rng = Rng::new(0xB17E);
    let n = 100_000usize;
    let data: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let k = TopK { ratio: 100.0 }.k_for(n);

    let (q, _) = encode_payload_with(
        ValueCodec::Int8,
        CompressKind::TopK,
        100.0,
        n, // one row: per-message-equivalent scale overhead
        0,
        1,
        OpDataKind::Activation,
        0,
        0,
        &data,
    );
    let (f, _) = encode_payload_with(
        ValueCodec::F32,
        CompressKind::TopK,
        100.0,
        n,
        0,
        1,
        OpDataKind::Activation,
        0,
        0,
        &data,
    );
    const OVERHEAD: usize = 96; // header + cfg + length fields + scale
    assert!(
        q.len() <= 5 * k + OVERHEAD,
        "int8-sparse {} bytes for k={k} (> 5 B/value)",
        q.len()
    );
    assert!(f.len() >= 8 * k, "f32-sparse should cost ≥ 8 B/value, got {}", f.len());
    // The chunked hot path (per-row scales, d_model=1600) stays under
    // 5.5 B/value including the scale overhead.
    let (qc, _) = encode_payload_with(
        ValueCodec::Int8,
        CompressKind::AdaTopK,
        100.0,
        1600,
        0,
        1,
        OpDataKind::Activation,
        0,
        0,
        &data,
    );
    let kc = (0..n).step_by(1600).map(|off| {
        TopK { ratio: 100.0 }.k_for((n - off).min(1600))
    });
    let kc: usize = kc.sum();
    assert!(
        (qc.len() as f64) <= 5.5 * kc as f64 + OVERHEAD as f64,
        "chunked int8-sparse {} bytes for k={kc}",
        qc.len()
    );
}

#[test]
fn link_encoder_steady_state_equals_oneshot_wrappers() {
    let mut rng = Rng::new(0x11C0);
    let n = 4 * 1600; // 4 feature rows
    let kinds = [
        (CompressKind::TopK, 100.0),
        (CompressKind::AdaTopK, 20.0),
        (CompressKind::RandomK, 50.0),
        (CompressKind::Int8, 4.0),
        (CompressKind::None, 1.0),
    ];
    for codec in [ValueCodec::F32, ValueCodec::Int8, ValueCodec::Int8Delta] {
        for (kind, ratio) in kinds {
            let mut enc = LinkEncoder::with_codec(kind, ratio, 1600, codec);
            for iter in 0..20u32 {
                let dense: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
                let (packet, wire) =
                    enc.encode(3, 4, OpDataKind::Activation, iter, iter % 4, &dense);
                let (oneshot, wire2) = encode_payload_with(
                    codec,
                    kind,
                    ratio,
                    1600,
                    3,
                    4,
                    OpDataKind::Activation,
                    iter,
                    iter % 4,
                    &dense,
                );
                assert_eq!(packet, oneshot, "{kind:?}/{codec:?} iter {iter}");
                assert_eq!(wire, wire2);
                // And the zero-copy decode reproduces the allocating decode.
                let (_od, want) = decode_payload(&packet, n).unwrap();
                let mut got = vec![f32::NAN; n];
                decode_payload_into(&packet, &mut got).unwrap();
                assert_eq!(got, want, "{kind:?}/{codec:?} iter {iter}");
            }
        }
    }
    // The F32-codec `new` constructor stays a differential oracle for the
    // seed wrapper.
    let dense: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
    let (a, wa) = LinkEncoder::new(CompressKind::TopK, 20.0, 1600)
        .encode(1, 2, OpDataKind::Gradient, 0, 0, &dense);
    let (b, wb) = fusionllm::worker::messages::encode_payload(
        CompressKind::TopK,
        20.0,
        1600,
        1,
        2,
        OpDataKind::Gradient,
        0,
        0,
        &dense,
    );
    assert_eq!(a, b);
    assert_eq!(wa, wb);
}
