//! End-to-end integration: the broker schedules the tiny model onto a
//! testbed, spawns PJRT workers, and trains over the simulated
//! geo-distributed pipeline. Requires `make artifacts`.

use fusionllm::broker::{self, Job};
use fusionllm::compress::CompressKind;
use fusionllm::pipeline::ScheduleKind;
use fusionllm::scheduler::replan::ReplanMode;

fn have_artifacts() -> bool {
    Job::default().artifacts_root.join("tiny/manifest.json").exists()
}

#[test]
fn tiny_training_loss_decreases_dense() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let job = Job { iters: 60, lr: 0.1, ..Job::default() };
    let report = broker::run(&job).unwrap();
    assert_eq!(report.losses.len(), 60);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let first = report.losses[..3].iter().sum::<f32>() / 3.0;
    let last = report.losses[57..].iter().sum::<f32>() / 3.0;
    // Random init sits near ln(256) ≈ 5.55; the Markov corpus is learnable.
    assert!(first > 4.5, "first={first}");
    assert!(last < first - 0.3, "no learning: first={first} last={last}");
    // Placement uses as many devices as stages.
    assert_eq!(report.placement.len(), 4);
    // Simulated geo latency is positive and wire bytes recorded.
    assert!(report.mean_sim_latency() > 0.0);
    assert!(report.wire_bytes[0] > 0.0);
}

#[test]
fn tiny_training_with_adatopk_still_learns() {
    if !have_artifacts() {
        return;
    }
    let dense = broker::run(&Job { iters: 50, lr: 0.1, ..Job::default() }).unwrap();
    let ada = broker::run(&Job {
        iters: 50,
        lr: 0.1,
        compress: CompressKind::AdaTopK,
        ratio: 20.0,
        ..Job::default()
    })
    .unwrap();
    // AdaTopK must still converge (Fig. 8): final loss within 15% of dense.
    let fd = dense.final_loss();
    let fa = ada.final_loss();
    assert!(fa.is_finite());
    assert!(fa < dense.losses[0], "adatopk did not learn: {fa}");
    assert!(fa < fd * 1.15 + 0.3, "adatopk {fa} vs dense {fd}");
    // And it must put fewer bytes on the wire.
    assert!(
        ada.wire_bytes[0] < dense.wire_bytes[0],
        "ada {} !< dense {}",
        ada.wire_bytes[0],
        dense.wire_bytes[0]
    );
}

#[test]
fn schedulers_produce_different_placements_same_numerics() {
    if !have_artifacts() {
        return;
    }
    let a = broker::run(&Job {
        iters: 6,
        scheduler: "opfence".into(),
        ..Job::default()
    })
    .unwrap();
    let b = broker::run(&Job {
        iters: 6,
        scheduler: "equal-number".into(),
        ..Job::default()
    })
    .unwrap();
    // Same seed, same data, same model => identical loss trajectories
    // regardless of placement (scheduling is numerics-neutral).
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
    // But the simulated geo latency differs (placement matters).
    assert_ne!(a.placement, b.placement);
}

#[test]
fn one_f_one_b_matches_gpipe_loss_trajectory_exactly() {
    // The schedule-interpreter differential: both kinds run the same
    // per-micro computations and accumulate gradients in the same fixed
    // order, so the trajectories must be *bitwise* identical.
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let gpipe = broker::run(&Job { iters: 20, lr: 0.1, ..Job::default() }).unwrap();
    let ofob = broker::run(&Job {
        iters: 20,
        lr: 0.1,
        pipeline: ScheduleKind::OneFOneB,
        ..Job::default()
    })
    .unwrap();
    assert_eq!(gpipe.losses.len(), ofob.losses.len());
    for (i, (g, o)) in gpipe.losses.iter().zip(&ofob.losses).enumerate() {
        assert_eq!(
            g.to_bits(),
            o.to_bits(),
            "iter {i}: gpipe {g} != 1f1b {o} (accumulation order leaked)"
        );
    }
    assert_eq!(ofob.pipeline, "1f1b");
    // And 1F1B actually learned (not just matched a broken run).
    assert!(ofob.final_loss() < ofob.losses[0] - 0.1);
}

#[test]
fn replan_auto_migrates_off_injected_straggler() {
    // Straggler e2e: stage 1's device is forced 30x slower; with
    // `--replan auto` the broker must re-partition mid-run (recorded in
    // TrainReport.replans) and keep the loss trajectory intact across the
    // parameter migration.
    if !have_artifacts() {
        return;
    }
    let job = Job {
        iters: 12,
        lr: 0.1,
        slow_stage: Some(1),
        slow_factor: 30.0,
        replan: ReplanMode::Auto,
        ..Job::default()
    };
    let r = broker::run(&job).unwrap();
    assert_eq!(r.losses.len(), 12);
    assert!(r.losses.iter().all(|l| l.is_finite()));
    let applied: Vec<_> = r.replans.iter().filter(|e| e.applied).collect();
    assert!(
        !applied.is_empty(),
        "30x straggler never triggered an applied replan: {:?}",
        r.replans
    );
    let ev = applied[0];
    assert!(ev.iter >= 1 && ev.iter < 12);
    assert!(ev.flagged.contains(&1), "stage 1 not flagged: {:?}", ev.flagged);
    assert_ne!(ev.from, ev.to, "replan event with no movement");
    assert!(ev.sim_after_s < ev.sim_before_s);
    // Final placement reflects the migration and training continued.
    let last_applied = r.replans.iter().rev().find(|e| e.applied).unwrap();
    assert_eq!(r.placement, last_applied.to);
    assert!(r.final_loss() < r.losses[0], "migration broke training");
    // The identical job without replanning must keep the static placement.
    let static_run = broker::run(&Job {
        replan: ReplanMode::Off,
        ..job.clone()
    })
    .unwrap();
    assert!(static_run.replans.is_empty());
    // Same seed + deterministic numerics: migration must not change the
    // loss trajectory (placement is numerics-neutral).
    for (a, b) in r.losses.iter().zip(&static_run.losses) {
        assert!((a - b).abs() < 1e-4, "replan changed numerics: {a} vs {b}");
    }
}

#[test]
fn replan_advise_logs_without_migrating() {
    if !have_artifacts() {
        return;
    }
    let r = broker::run(&Job {
        iters: 8,
        lr: 0.1,
        slow_stage: Some(1),
        slow_factor: 30.0,
        replan: ReplanMode::Advise,
        ..Job::default()
    })
    .unwrap();
    // Recommendations recorded, none applied, placement untouched.
    assert!(!r.replans.is_empty(), "advise mode recorded no recommendation");
    assert!(r.replans.iter().all(|e| !e.applied));
    assert_eq!(r.placement.len(), 4);
    assert_eq!(r.replans[0].from, r.placement);
}

#[test]
fn int8_compression_roundtrip_trains() {
    if !have_artifacts() {
        return;
    }
    let r = broker::run(&Job {
        iters: 30,
        lr: 0.1,
        compress: CompressKind::Int8,
        ..Job::default()
    })
    .unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(r.final_loss() < r.losses[0]);
}

#[test]
fn adam_optimizer_trains() {
    if !have_artifacts() {
        return;
    }
    let r = broker::run(&Job {
        iters: 25,
        lr: 0.003,
        optimizer: "adam".into(),
        ..Job::default()
    })
    .unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(
        r.final_loss() < r.losses[0] - 0.1,
        "adam did not learn: {} -> {}",
        r.losses[0],
        r.final_loss()
    );
}
