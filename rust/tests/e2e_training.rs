//! End-to-end integration: the broker schedules the tiny model onto a
//! testbed, spawns PJRT workers, and trains over the simulated
//! geo-distributed pipeline. Requires `make artifacts`.

use fusionllm::broker::{self, Job};
use fusionllm::compress::CompressKind;

fn have_artifacts() -> bool {
    Job::default().artifacts_root.join("tiny/manifest.json").exists()
}

#[test]
fn tiny_training_loss_decreases_dense() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let job = Job { iters: 60, lr: 0.1, ..Job::default() };
    let report = broker::run(&job).unwrap();
    assert_eq!(report.losses.len(), 60);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let first = report.losses[..3].iter().sum::<f32>() / 3.0;
    let last = report.losses[57..].iter().sum::<f32>() / 3.0;
    // Random init sits near ln(256) ≈ 5.55; the Markov corpus is learnable.
    assert!(first > 4.5, "first={first}");
    assert!(last < first - 0.3, "no learning: first={first} last={last}");
    // Placement uses as many devices as stages.
    assert_eq!(report.placement.len(), 4);
    // Simulated geo latency is positive and wire bytes recorded.
    assert!(report.mean_sim_latency() > 0.0);
    assert!(report.wire_bytes[0] > 0.0);
}

#[test]
fn tiny_training_with_adatopk_still_learns() {
    if !have_artifacts() {
        return;
    }
    let dense = broker::run(&Job { iters: 50, lr: 0.1, ..Job::default() }).unwrap();
    let ada = broker::run(&Job {
        iters: 50,
        lr: 0.1,
        compress: CompressKind::AdaTopK,
        ratio: 20.0,
        ..Job::default()
    })
    .unwrap();
    // AdaTopK must still converge (Fig. 8): final loss within 15% of dense.
    let fd = dense.final_loss();
    let fa = ada.final_loss();
    assert!(fa.is_finite());
    assert!(fa < dense.losses[0], "adatopk did not learn: {fa}");
    assert!(fa < fd * 1.15 + 0.3, "adatopk {fa} vs dense {fd}");
    // And it must put fewer bytes on the wire.
    assert!(
        ada.wire_bytes[0] < dense.wire_bytes[0],
        "ada {} !< dense {}",
        ada.wire_bytes[0],
        dense.wire_bytes[0]
    );
}

#[test]
fn schedulers_produce_different_placements_same_numerics() {
    if !have_artifacts() {
        return;
    }
    let a = broker::run(&Job {
        iters: 6,
        scheduler: "opfence".into(),
        ..Job::default()
    })
    .unwrap();
    let b = broker::run(&Job {
        iters: 6,
        scheduler: "equal-number".into(),
        ..Job::default()
    })
    .unwrap();
    // Same seed, same data, same model => identical loss trajectories
    // regardless of placement (scheduling is numerics-neutral).
    for (x, y) in a.losses.iter().zip(&b.losses) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
    // But the simulated geo latency differs (placement matters).
    assert_ne!(a.placement, b.placement);
}

#[test]
fn int8_compression_roundtrip_trains() {
    if !have_artifacts() {
        return;
    }
    let r = broker::run(&Job {
        iters: 30,
        lr: 0.1,
        compress: CompressKind::Int8,
        ..Job::default()
    })
    .unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(r.final_loss() < r.losses[0]);
}

#[test]
fn adam_optimizer_trains() {
    if !have_artifacts() {
        return;
    }
    let r = broker::run(&Job {
        iters: 25,
        lr: 0.003,
        optimizer: "adam".into(),
        ..Job::default()
    })
    .unwrap();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(
        r.final_loss() < r.losses[0] - 0.1,
        "adam did not learn: {} -> {}",
        r.losses[0],
        r.final_loss()
    );
}
