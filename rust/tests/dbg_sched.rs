use fusionllm::cluster::testbed::testbed1;
use fusionllm::cost::throughput::{dense_bytes, evaluate, PipelineParams};
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::scheduler::{by_name, Scheduler};

#[test]
fn dbg_decomposition() {
    let tb = testbed1(1);
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let params = PipelineParams { n_micro: 2, micro_size: 3, include_bwd: true };
    for name in ["opfence", "equal-number", "equal-compute"] {
        let p = by_name(name).unwrap().schedule(&dag, &tb).unwrap();
        let e = evaluate(&dag, &p, &tb, params, &dense_bytes);
        let comm: f64 = e.per_node.iter().map(|c| c.comm_s).sum();
        let comp: f64 = e.per_node.iter().map(|c| c.comp_s).sum();
        println!("{name}: t_pipe={:.2} t_lat={:.2} comm={comm:.2} comp={comp:.2} bneck={:.2}@{} used={}",
            e.t_pipe, e.t_lat, e.bottleneck_s, e.bottleneck_node, e.per_node.len());
        // top 3 comm nodes
        let mut pn = e.per_node.clone();
        pn.sort_by(|a,b| b.comm_s.partial_cmp(&a.comm_s).unwrap());
        for c in pn.iter().take(4) { println!("   node {} comm={:.2} comp={:.3}", c.node, c.comm_s, c.comp_s); }
    }
}
