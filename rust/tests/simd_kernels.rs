//! SIMD-vs-scalar differential gates for every `util::simd` wire kernel.
//!
//! The transport/overlap/mesh suites pin *bitwise* losses across paths, so
//! the vector kernels must be bit-identical to their scalar references —
//! these tests enforce that over randomized lengths (including every
//! ragged tail around the 4/8-lane widths), adversarial float values
//! (half-ulp rounding boundaries, subnormals, |x| ≥ 2^31, infinities,
//! NaN) and duplicate/out-of-range scatter indices, at every dispatch
//! level the host supports (`Level::supported()` — SSE2 is exercised even
//! on AVX2 machines).

use fusionllm::util::fnv;
use fusionllm::util::rng::Rng;
use fusionllm::util::simd::{self, Level, ScatterError};

/// Ragged tails around the 4-lane (SSE2) and 8-lane (AVX2) widths, plus
/// block-boundary cases around the 64-index scatter blocks.
const LENS: [usize; 25] = [
    0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129, 255, 256,
    1000, 4097,
];

fn rand_values(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|_| (rng.f32() - 0.5) * 10f32.powi(rng.range(-3, 4) as i32))
        .collect()
}

/// Every adversarial f32 the quantizer contract must cover bit-exactly.
fn nasty_values() -> Vec<f32> {
    let mut v = vec![
        0.0,
        -0.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-41, // subnormal
        f32::MAX,
        f32::MIN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        8_388_608.0,      // 2^23
        16_777_216.0,     // 2^24
        2_147_483_520.0,  // largest f32 < 2^31
        2_147_483_648.0,  // 2^31
        -2_147_483_648.0,
        8.4e9,
        0.5,
        -0.5,
        1.5,
        2.5,
        -2.5,
        126.5,
        127.4,
        127.5,
        -127.5,
        200.0,
        -200.0,
    ];
    // Exact half-way rounding boundaries: with scale 0.5, k·0.25 puts
    // every other value exactly on a .5 code boundary.
    for k in -600i32..=600 {
        v.push(k as f32 * 0.25);
    }
    v
}

#[test]
fn supported_levels_start_with_scalar() {
    let levels = Level::supported();
    assert_eq!(levels[0], Level::Scalar);
    // level() returns something the machine supports.
    assert!(levels.contains(&simd::level()) || simd::level() == Level::Scalar);
}

#[test]
fn quantize_codes_bitwise_identical() {
    let mut rng = Rng::new(0xC0DE);
    let scales = [1.0f32, 0.5, 0.031_25, 7.3e-3, 1e-30, f32::MIN_POSITIVE];
    for lv in Level::supported() {
        for &n in &LENS {
            let xs = rand_values(n, &mut rng);
            for &scale in &scales {
                let mut want = Vec::new();
                simd::quantize_codes_scalar(&xs, scale, &mut want);
                let mut got = Vec::new();
                simd::quantize_codes_at(lv, &xs, scale, &mut got);
                assert_eq!(got, want, "level={} n={n} scale={scale}", lv.name());
            }
        }
        // Adversarial values, every scale.
        let xs = nasty_values();
        for &scale in &scales {
            let mut want = Vec::new();
            simd::quantize_codes_scalar(&xs, scale, &mut want);
            let mut got = Vec::new();
            simd::quantize_codes_at(lv, &xs, scale, &mut got);
            assert_eq!(got, want, "nasty level={} scale={scale}", lv.name());
        }
    }
}

#[test]
fn dequant_bitwise_identical() {
    let mut rng = Rng::new(0xDEC0);
    for lv in Level::supported() {
        for &n in &LENS {
            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            for scale in [1.0f32, 0.25, 3.7e-5] {
                let mut want = vec![9.0f32; n];
                simd::dequant_into_scalar(&codes, scale, &mut want);
                let mut got = vec![9.0f32; n];
                simd::dequant_into_at(lv, &codes, scale, &mut got);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={} n={n} scale={scale}", lv.name());
            }
        }
    }
}

#[test]
fn dequant_zip_length_semantics() {
    // Excess on either side stays untouched, like the scalar zip loops.
    let codes = vec![0x81u8; 10]; // -127
    for lv in Level::supported() {
        let mut out = vec![5.0f32; 16];
        simd::dequant_into_at(lv, &codes, 1.0, &mut out);
        assert!(out[..10].iter().all(|&v| v == -127.0), "level={}", lv.name());
        assert!(out[10..].iter().all(|&v| v == 5.0), "level={}", lv.name());
        let mut short = vec![5.0f32; 4];
        simd::dequant_into_at(lv, &codes, 1.0, &mut short);
        assert!(short.iter().all(|&v| v == -127.0));
    }
}

#[test]
fn max_abs_bitwise_identical() {
    let mut rng = Rng::new(0xAB5);
    for lv in Level::supported() {
        for &n in &LENS {
            let mut xs = rand_values(n, &mut rng);
            if n > 2 {
                xs[n / 2] = f32::INFINITY;
                xs[n - 1] = -0.0;
            }
            let want = simd::max_abs_scalar(&xs);
            let got = simd::max_abs_at(lv, &xs);
            assert_eq!(got.to_bits(), want.to_bits(), "level={} n={n}", lv.name());
        }
    }
}

#[test]
fn abs_bits_bitwise_identical() {
    let mut rng = Rng::new(0xB175);
    for lv in Level::supported() {
        for &n in &LENS {
            let mut xs = rand_values(n, &mut rng);
            if n > 1 {
                xs[0] = f32::NAN; // pure bit op: NaN is in-contract here
                xs[n - 1] = -0.0;
            }
            let mut want = vec![0u32; n];
            simd::abs_bits_scalar(&xs, &mut want);
            let mut got = vec![1u32; n];
            simd::abs_bits_at(lv, &xs, &mut got);
            assert_eq!(got, want, "level={} n={n}", lv.name());
        }
    }
}

#[test]
fn gather_bitwise_identical() {
    let mut rng = Rng::new(0x6A7);
    let src = rand_values(5000, &mut rng);
    for lv in Level::supported() {
        for &n in &LENS {
            let idx: Vec<u32> = (0..n).map(|_| rng.below(src.len() as u64) as u32).collect();
            let mut want = vec![7.0f32];
            simd::gather_f32_scalar(&src, &idx, &mut want);
            let mut got = vec![7.0f32];
            simd::gather_f32_at(lv, &src, &idx, &mut got);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "level={} n={n}", lv.name());
        }
    }
}

#[test]
fn le_moves_bitwise_identical() {
    let mut rng = Rng::new(0x1E1E);
    for lv in Level::supported() {
        for &n in &LENS {
            let xs = rand_values(n, &mut rng);
            let mut want = vec![0xAAu8];
            simd::extend_f32_le_scalar(&mut want, &xs);
            let mut got = vec![0xAAu8];
            simd::extend_f32_le_at(lv, &mut got, &xs);
            assert_eq!(got, want, "f32 level={} n={n}", lv.name());

            let us: Vec<u32> = (0..n as u32).map(|i| i.wrapping_mul(0x9E37_79B9)).collect();
            let mut want = Vec::new();
            simd::extend_u32_le_scalar(&mut want, &us);
            let mut got = Vec::new();
            simd::extend_u32_le_at(lv, &mut got, &us);
            assert_eq!(got, want, "u32 level={} n={n}", lv.name());

            // Round-trip decode, including a ragged trailing byte.
            let mut bytes = Vec::new();
            simd::extend_f32_le_scalar(&mut bytes, &xs);
            bytes.push(0xEE);
            let mut dst = vec![3.0f32; n];
            simd::f32_from_le_at(lv, &bytes, &mut dst);
            let db: Vec<u32> = dst.iter().map(|v| v.to_bits()).collect();
            let xb: Vec<u32> = xs.iter().map(|v| v.to_bits()).collect();
            assert_eq!(db, xb, "from_le level={} n={n}", lv.name());
        }
    }
}

fn idx_bytes(idx: &[u32]) -> Vec<u8> {
    let mut out = Vec::new();
    simd::extend_u32_le_scalar(&mut out, idx);
    out
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    simd::extend_f32_le_scalar(&mut out, xs);
    out
}

#[test]
fn scatter_f32_view_matches_scalar_with_duplicates() {
    let mut rng = Rng::new(0x5CA7);
    for lv in Level::supported() {
        for &n in &LENS {
            let dense_len = (n * 2).max(8);
            // Duplicate-heavy index stream: last write must win, in order.
            let idx: Vec<u32> =
                (0..n).map(|_| rng.below(dense_len as u64 / 2) as u32).collect();
            let vals = rand_values(n, &mut rng);
            let (ib, vb) = (idx_bytes(&idx), f32_bytes(&vals));
            let mut want = vec![0.0f32; dense_len];
            simd::scatter_f32_view_scalar(&ib, &vb, &mut want).unwrap();
            let mut got = vec![0.0f32; dense_len];
            simd::scatter_f32_view_at(lv, &ib, &vb, &mut got).unwrap();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "level={} n={n}", lv.name());
        }
    }
}

#[test]
fn scatter_view_rejects_out_of_range_index() {
    for lv in Level::supported() {
        let idx = [3u32, 1, 99, 0]; // 99 is out of range for dense_len 8
        let vals = [1.0f32, 2.0, 3.0, 4.0];
        let (ib, vb) = (idx_bytes(&idx), f32_bytes(&vals));
        let mut dense = vec![0.0f32; 8];
        assert_eq!(
            simd::scatter_f32_view_at(lv, &ib, &vb, &mut dense),
            Err(ScatterError::Index),
            "level={}",
            lv.name()
        );
        let codes = [1u8, 2, 3, 4];
        assert_eq!(
            simd::scatter_int8_view_at(lv, &ib, &codes, 1.0, &mut dense),
            Err(ScatterError::Index),
            "level={}",
            lv.name()
        );
        let scales = f32_bytes(&[1.0; 16]);
        assert_eq!(
            simd::scatter_int8_rows_view_at(lv, &ib, &codes, &scales, 8, &mut dense),
            Err(ScatterError::Index),
            "level={}",
            lv.name()
        );
    }
}

#[test]
fn scatter_int8_view_matches_scalar() {
    let mut rng = Rng::new(0x1278);
    for lv in Level::supported() {
        for &n in &LENS {
            let dense_len = (n * 2).max(8);
            let idx: Vec<u32> = (0..n).map(|_| rng.below(dense_len as u64) as u32).collect();
            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let ib = idx_bytes(&idx);
            for scale in [1.0f32, 0.03] {
                let mut want = vec![0.0f32; dense_len];
                simd::scatter_int8_view_scalar(&ib, &codes, scale, &mut want).unwrap();
                let mut got = vec![0.0f32; dense_len];
                simd::scatter_int8_view_at(lv, &ib, &codes, scale, &mut got).unwrap();
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={} n={n} scale={scale}", lv.name());
            }
        }
    }
}

#[test]
fn scatter_int8_rows_view_matches_scalar() {
    let mut rng = Rng::new(0x2055);
    for lv in Level::supported() {
        for &n in &LENS {
            for chunk in [1usize, 3, 8, 64] {
                let dense_len = (n * 2).max(8);
                // Index-sorted support (the Top-K shape: runs share rows).
                let mut idx: Vec<u32> =
                    (0..n).map(|_| rng.below(dense_len as u64) as u32).collect();
                idx.sort_unstable();
                let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                let n_rows = (dense_len + chunk - 1) / chunk;
                let scales: Vec<f32> = (0..n_rows).map(|_| rng.f32() + 0.01).collect();
                let (ib, sb) = (idx_bytes(&idx), f32_bytes(&scales));
                let mut want = vec![0.0f32; dense_len];
                simd::scatter_int8_rows_view_scalar(&ib, &codes, &sb, chunk, &mut want)
                    .unwrap();
                let mut got = vec![0.0f32; dense_len];
                simd::scatter_int8_rows_view_at(lv, &ib, &codes, &sb, chunk, &mut got)
                    .unwrap();
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "level={} n={n} chunk={chunk}", lv.name());

                // In-memory variant against the same reference.
                let mut mem = vec![0.0f32; dense_len];
                simd::scatter_int8_rows_mem_at(lv, &idx, &codes, &scales, chunk, &mut mem);
                let mb: Vec<u32> = mem.iter().map(|v| v.to_bits()).collect();
                assert_eq!(mb, wb, "mem level={} n={n} chunk={chunk}", lv.name());
            }
        }
    }
}

#[test]
fn scatter_rows_view_rejects_short_scales() {
    for lv in Level::supported() {
        let idx = [0u32, 9]; // row 9/chunk=1 → needs scales[9], region has 2
        let codes = [5u8, 6];
        let scales = f32_bytes(&[1.0, 1.0]);
        let mut dense = vec![0.0f32; 16];
        assert_eq!(
            simd::scatter_int8_rows_view_at(lv, &idx_bytes(&idx), &codes, &scales, 1, &mut dense),
            Err(ScatterError::Scale),
            "level={}",
            lv.name()
        );
    }
}

#[test]
fn mem_scatters_match_scalar() {
    let mut rng = Rng::new(0x3E3A);
    for lv in Level::supported() {
        for &n in &LENS {
            let dense_len = (n * 2).max(8);
            let idx: Vec<u32> =
                (0..n).map(|_| rng.below(dense_len as u64 / 2) as u32).collect();
            let vals = rand_values(n, &mut rng);
            let mut want = vec![0.0f32; dense_len];
            simd::scatter_f32_mem_scalar(&idx, &vals, &mut want);
            let mut got = vec![0.0f32; dense_len];
            simd::scatter_f32_mem_at(lv, &idx, &vals, &mut got);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "f32 level={} n={n}", lv.name());

            let codes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let mut want = vec![0.0f32; dense_len];
            simd::scatter_int8_mem_scalar(&idx, &codes, 0.5, &mut want);
            let mut got = vec![0.0f32; dense_len];
            simd::scatter_int8_mem_at(lv, &idx, &codes, 0.5, &mut got);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "int8 level={} n={n}", lv.name());
        }
    }
}

#[test]
fn fnv_levels_match_scalar() {
    let mut rng = Rng::new(0xF2F);
    for &n in &LENS {
        let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let want = fnv::fnv1a64_scalar(&data);
        for lv in Level::supported() {
            assert_eq!(fnv::fnv1a64_at(lv, &data), want, "level={} n={n}", lv.name());
        }
        assert_eq!(fnv::fnv1a64(&data), want, "dispatched n={n}");
    }
}

/// End-to-end: the whole compress → encode → decode pipeline must be
/// bitwise identical between the dispatched kernels and a forced-scalar
/// decode of the same wire bytes (the wire-path differential the CI
/// forced-scalar job re-runs with `FUSIONLLM_FORCE_SCALAR=1`).
#[test]
fn wire_roundtrip_same_bytes_for_all_levels() {
    use fusionllm::compress::sparsify::{Compressor, Int8Quantizer, TopK};
    let mut rng = Rng::new(0xE2E);
    let xs = rand_values(3000, &mut rng);
    for comp in [&TopK { ratio: 20.0 } as &dyn Compressor, &Int8Quantizer] {
        let c = comp.compress(&xs);
        let mut out = vec![0.0f32; xs.len()];
        comp.decompress(&c, &mut out);
        // Kept values survive exactly (TopK) / within quant error (int8),
        // and a second decompress is bit-identical (determinism).
        let mut again = vec![0.0f32; xs.len()];
        comp.decompress(&c, &mut again);
        let a: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = again.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "{}", comp.name());
    }
}
